"""Registry-wide estimator conformance suite.

ONE parameterized contract over every ``registry.list_estimators()`` entry —
any estimator added to the registry is automatically held to the same
five-function protocol the consumers (make_feature_map, RM attention, the
serving engine, the sharded execution layer) rely on:

  * ``apply`` produces ``output_dim(plan)`` columns;
  * plans are hashable and jit-STATIC: equal plans (built twice) hit one
    trace — the property that lets plans ride through jit/scan/shard_map as
    compile-time constants;
  * ``to_json``/``from_json`` is a lossless round-trip (cross-host repro);
  * the fused Pallas path (interpret mode on CPU) matches the reference
    path to 1e-5;
  * the reported §4.2 ``truncation_bias`` is monotonically non-increasing
    in n_max: widening the series coverage never increases the worst-case
    dropped mass (guaranteed by the BIAS_TAIL_DEGREES coefficient window —
    see repro.core.plan).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ExponentialDotProductKernel, PolynomialKernel, registry

ESTIMATORS = registry.list_estimators()
KERN = ExponentialDotProductKernel(1.0)


def _build(name, *, input_dim=10, num_features=192, **kw):
    est = registry.get(name)
    kw.setdefault("measure", "proportional")
    kw.setdefault("seed", 0)
    plan = est.make_plan(KERN, input_dim, num_features, **kw)
    params = est.init_params(plan, jax.random.PRNGKey(0))
    return est, plan, params


@pytest.mark.parametrize("name", ESTIMATORS)
def test_apply_shape_matches_output_dim(name):
    est, plan, params = _build(name)
    x = jax.random.normal(jax.random.PRNGKey(1), (7, 10)) * 0.3
    z = est.apply(plan, params, x, use_pallas=False)
    assert z.shape == (7, est.output_dim(plan))
    assert np.isfinite(np.asarray(z)).all()
    # batch shape passes through
    z3 = est.apply(plan, params, x.reshape(7, 1, 10), use_pallas=False)
    assert z3.shape == (7, 1, est.output_dim(plan))


@pytest.mark.parametrize("name", ESTIMATORS)
def test_plan_hashable_and_jit_static(name):
    est, plan, params = _build(name)
    est2, plan2, _ = _build(name)   # independently constructed, equal
    assert plan == plan2
    assert hash(plan) == hash(plan2)

    traces = []

    @jax.jit
    def apply_static(x):
        # rebuilt-per-call closure would retrace if plan weren't static
        traces.append(1)
        return est.apply(plan, params, x, use_pallas=False)

    from functools import partial

    @partial(jax.jit, static_argnums=0)
    def apply_arg(p, prm, x):
        traces.append(1)
        return est.apply(p, prm, x, use_pallas=False)

    x = jax.random.normal(jax.random.PRNGKey(2), (4, 10)) * 0.3
    apply_static(x)
    apply_static(x)
    assert len(traces) == 1
    traces.clear()
    apply_arg(plan, params, x)
    apply_arg(plan2, params, x)     # equal plan object -> cache hit
    assert len(traces) == 1


@pytest.mark.parametrize("name", ESTIMATORS)
def test_plan_json_round_trip(name):
    _, plan, _ = _build(name, seed=1234)
    rt = type(plan).from_json(plan.to_json())
    assert rt == plan
    assert hash(rt) == hash(plan)
    assert rt.seed == 1234


@pytest.mark.parametrize("name", ESTIMATORS)
def test_pallas_interpret_matches_reference(name):
    est, plan, params = _build(name)
    x = jax.random.normal(jax.random.PRNGKey(3), (9, 10)) * 0.25
    ref = est.apply(plan, params, x, use_pallas=False)
    got = est.apply(plan, params, x, use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# which families currently carry the fused featurize+attention capability
# (kernels/rm_attention/fused.py). A new registry entry missing from this
# map only has to satisfy the generic contract below.
_EXPECTED_FUSED_ATTENTION = {"rm": True, "tensor_sketch": False,
                             "ctr": False}


@pytest.mark.parametrize("name", ESTIMATORS)
def test_fused_attention_capability_contract(name):
    """``fused_attention_supported`` and ``pack_fused`` travel together, and
    the packed tensors satisfy the layout the fused attention kernels
    consume: w [max_degree, F, d], per-column degree <= max_degree, finite
    scales. Families without the capability must leave pack_fused unset so
    the model layers' fallback test is a single flag read."""
    est, plan, params = _build(name)
    if name in _EXPECTED_FUSED_ATTENTION:
        assert est.fused_attention_supported == _EXPECTED_FUSED_ATTENTION[
            name]
    if not est.fused_attention_supported:
        assert est.pack_fused is None
        return
    assert est.pack_fused is not None
    w, col_deg, col_scale = est.pack_fused(plan, params)
    w = jnp.asarray(w)
    deg = np.asarray(col_deg)
    sc = np.asarray(col_scale, dtype=np.float64)
    assert w.ndim == 3
    assert w.shape[2] == 10                    # input_dim from _build
    assert deg.shape == (w.shape[1],)
    assert sc.shape == (w.shape[1],)
    assert int(deg.max()) <= w.shape[0]
    assert int(deg.min()) >= 0
    assert np.isfinite(sc).all()


@pytest.mark.parametrize("name", ESTIMATORS)
def test_fused_attention_matches_two_launch(name):
    """For capable families, the fused causal op over the packed tensors
    matches featurize-then-attend at 1e-5; incapable families are exactly
    the ones the model layers route to the two-launch composition."""
    from repro.kernels.rm_attention import (rm_attention_causal,
                                            rm_attention_fused_causal)

    est, plan, params = _build(name)
    if not est.fused_attention_supported:
        pytest.skip(f"{name} takes the two-launch fallback by contract")
    w, col_deg, col_scale = est.pack_fused(plan, params)
    w = jnp.asarray(w)
    b, h, t, dv = 1, 2, 24, 6
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(21), 3)
    q = jax.random.normal(kq, (b, h, t, 10)) * 0.3
    k = jax.random.normal(kk, (b, h, t, 10)) * 0.3
    v = jax.random.normal(kv, (b, h, t, dv))
    got = rm_attention_fused_causal(q, k, v, w, col_deg, col_scale,
                                    chunk=8, use_pallas=True,
                                    interpret=True)
    z = est.apply(plan, params, jnp.concatenate([q, k], axis=0),
                  use_pallas=False)
    zq, zk = z[:b], z[b:]
    want = rm_attention_causal(zq, zk, v, chunk=8, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", ESTIMATORS)
def test_truncation_bias_monotone_in_n_max(name):
    est = registry.get(name)
    biases = []
    for n_max in (4, 8, 12, 16):
        plan = est.make_plan(KERN, 8, 512, measure="proportional",
                             n_max=n_max, seed=0)
        biases.append(est.truncation_bias(plan, 1.0))
    assert all(b >= 0.0 for b in biases)
    assert biases[-1] > 0.0  # the tail window keeps the diagnostic honest
    for lo, hi in zip(biases[1:], biases[:-1]):
        assert lo <= hi + 1e-12, biases


@pytest.mark.parametrize("name", ESTIMATORS)
def test_truncation_bias_zero_radius_and_poly(name):
    """Finite-series kernels covered by n_max report (near-)zero bias."""
    est = registry.get(name)
    plan = est.make_plan(PolynomialKernel(3, 1.0), 6, 256,
                         measure="proportional", n_max=8, seed=0)
    assert est.truncation_bias(plan, 1.0) == pytest.approx(0.0, abs=1e-12)


# ---------------------------------------------------------------------------
# edge-shape fuzz: degenerate/boundary launches through all three fused
# kernels in interpret mode, checked against the reference path
# ---------------------------------------------------------------------------
def _check_fused_matches_ref(est, plan, params, x):
    ref = est.apply(plan, params, x, use_pallas=False)
    got = est.apply(plan, params, x, use_pallas=True, interpret=True)
    assert got.shape == ref.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", ESTIMATORS)
def test_edge_batch_zero(name):
    """batch=0 row chunks skip the padded launch but keep the shape."""
    est, plan, params = _build(name)
    x = jnp.zeros((0, 10))
    z = est.apply(plan, params, x, use_pallas=True, interpret=True)
    assert z.shape == (0, est.output_dim(plan))
    # zero batch inside a leading batch dim too
    z3 = est.apply(plan, params, jnp.zeros((2, 0, 10)),
                   use_pallas=True, interpret=True)
    assert z3.shape == (2, 0, est.output_dim(plan))


@pytest.mark.parametrize("name", ESTIMATORS)
def test_edge_input_dim_one(name):
    """d=1: the thinnest possible projection axis."""
    est, plan, params = _build(name, input_dim=1, num_features=32)
    x = jax.random.normal(jax.random.PRNGKey(11), (5, 1)) * 0.3
    _check_fused_matches_ref(est, plan, params, x)


@pytest.mark.parametrize("name", ESTIMATORS)
def test_edge_single_tile(name):
    """F and batch at the smallest ladder tile: exactly one grid cell."""
    est, plan, params = _build(name, input_dim=4, num_features=8)
    x = jax.random.normal(jax.random.PRNGKey(12), (8, 4)) * 0.3
    _check_fused_matches_ref(est, plan, params, x)


@pytest.mark.parametrize("name", ESTIMATORS)
def test_edge_max_degree_one(name):
    """n_max=1 plans: product depth exactly 1 in every fused kernel."""
    est, plan, params = _build(name, num_features=48, n_max=1)
    assert plan.max_degree <= 1
    x = jax.random.normal(jax.random.PRNGKey(13), (6, 10)) * 0.3
    _check_fused_matches_ref(est, plan, params, x)


@pytest.mark.parametrize("name", ESTIMATORS)
def test_edge_noncontiguous_and_uneven_chunks(name):
    """Non-contiguous (strided) inputs and uneven row chunking agree with
    the contiguous single-shot application."""
    est, plan, params = _build(name)
    X = np.asarray(jax.random.normal(jax.random.PRNGKey(14), (33, 10)) * 0.3)
    strided = X[::2]                      # non-contiguous numpy view
    assert not strided.flags["C_CONTIGUOUS"]
    ref = est.apply(plan, params, jnp.asarray(strided.copy()),
                    use_pallas=True, interpret=True)
    got = est.apply(plan, params, strided, use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
    # registry.featurize_chunked with a chunk that doesn't divide N: the
    # final short chunk still goes through the padded fused launch
    full = est.apply(plan, params, jnp.asarray(X),
                     use_pallas=True, interpret=True)
    chunked = registry.featurize_chunked(
        lambda Z: est.apply(plan, params, Z, use_pallas=True,
                            interpret=True),
        jnp.asarray(X), row_chunk=5)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# progressive growth (core.doubling): every family must double its budget
# without redrawing — the prefix-identity contract docs/adaptive.md names
# ---------------------------------------------------------------------------
from repro.core import GrowableFeatureMap, make_growable_feature_map  # noqa: E402


def _growable(name, **kw):
    kw.setdefault("base_features", 48)
    kw.setdefault("measure", "proportional")
    return make_growable_feature_map(KERN, 10, jax.random.PRNGKey(5),
                                     estimator=name, **kw)


@pytest.mark.parametrize("name", ESTIMATORS)
def test_growth_prefix_bit_identical(name):
    """grow() appends generations; the raw feature prefix is BIT-identical
    (np.array_equal, not allclose) and the rescaled output differs from it
    by exactly the one global 1/sqrt(G) multiply."""
    gm = _growable(name)
    X = jax.random.normal(jax.random.PRNGKey(6), (5, 10)) * 0.3
    raw1 = np.asarray(gm.apply(X, rescale=False, use_pallas=False))
    g2 = gm.grow()
    g4 = g2.grow()
    assert (g2.n_generations, g4.n_generations) == (2, 4)
    raw2 = np.asarray(g2.apply(X, rescale=False, use_pallas=False))
    raw4 = np.asarray(g4.apply(X, rescale=False, use_pallas=False))
    assert raw2.shape[1] == 2 * raw1.shape[1]
    assert np.array_equal(raw2[:, :raw1.shape[1]], raw1)
    assert np.array_equal(raw4[:, :raw2.shape[1]], raw2)
    # growth path independence: 1 -> 4 directly equals 1 -> 2 -> 4
    direct = gm.grow_to_generations(4)
    assert np.array_equal(
        np.asarray(direct.apply(X, rescale=False, use_pallas=False)), raw4)
    # the scaled output is raw * 1/sqrt(G), nothing else
    scaled = np.asarray(g4.apply(X, use_pallas=False))
    np.testing.assert_allclose(scaled, raw4 / np.sqrt(4.0),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("name", ESTIMATORS)
def test_growth_eps_monotone_and_gram(name):
    """eps_at tightens with every doubling, and the generation-summed Gram
    still estimates the kernel (sanity: error shrinks or holds as G grows,
    up to sampling noise at these tiny budgets)."""
    gm = _growable(name)
    X = np.asarray(jax.random.normal(jax.random.PRNGKey(7), (6, 10)) * 0.3)
    eps = [gm.eps_at(0.05)]
    maps = [gm]
    for _ in range(3):
        maps.append(maps[-1].grow())
        eps.append(maps[-1].eps_at(0.05))
    assert all(b < a for a, b in zip(eps, eps[1:])), eps
    # estimate_gram == the scaled features' explicit Gram
    g = maps[2]
    Z = np.asarray(g.apply(X, use_pallas=False))
    G_est = np.asarray(g.estimate_gram(X, use_pallas=False))
    np.testing.assert_allclose(G_est, Z @ Z.T, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", ESTIMATORS)
def test_growth_json_round_trip(name):
    """to_json stores (plan, key, G) only; from_json redraws the stacked
    params bit-identically — growth state is exactly reproducible
    cross-host."""
    gm = _growable(name).grow_to_generations(3)
    rt = GrowableFeatureMap.from_json(gm.to_json(), kernel=KERN)
    assert rt.n_generations == 3
    assert rt.plan == gm.plan
    X = jax.random.normal(jax.random.PRNGKey(8), (4, 10)) * 0.3
    assert np.array_equal(
        np.asarray(rt.apply(X, rescale=False, use_pallas=False)),
        np.asarray(gm.apply(X, rescale=False, use_pallas=False)))
    # the bound context survives the trip
    assert rt.eps_at(0.05) == pytest.approx(gm.eps_at(0.05))
    # without a kernel the bound side fails LOUDLY, the map still applies
    bare = GrowableFeatureMap.from_json(gm.to_json())
    with pytest.raises(ValueError, match="kernel"):
        bare.eps_at(0.05)


@pytest.mark.parametrize("name", ESTIMATORS)
def test_growth_matches_sharded_layout(name):
    """A G-generation growable map computes the SAME raw feature layout as
    the distributed S=G shard draw — growth and sharding are one fold_in
    contract (distributed/estimator.py)."""
    from repro.distributed.estimator import shard_init_params

    gm = _growable(name).grow_to_generations(2)
    est = registry.get(name)
    X = jax.random.normal(jax.random.PRNGKey(9), (3, 10)) * 0.3
    stacked = shard_init_params(name, gm.plan,
                                jnp.asarray(gm.key_data, jnp.uint32), 2)
    parts = []
    for s in range(2):
        p = jax.tree_util.tree_map(lambda a: a[s], stacked)
        parts.append(np.asarray(est.apply(gm.plan, p, X,
                                          use_pallas=False)))
    want = np.concatenate(parts, axis=-1)
    got = np.asarray(gm.apply(X, rescale=False, use_pallas=False))
    assert np.array_equal(got, want)
