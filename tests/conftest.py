"""Shared test configuration.

Hypothesis runs DERANDOMIZED by default so tier-1 is bit-reproducible: the
same examples are generated on every run/machine (CI included), and
``deadline=None`` keeps jit-compile time from tripping per-example
deadlines. Export ``HYPOTHESIS_PROFILE=dev`` locally to hunt with fresh
random examples.
"""
import os

try:
    from hypothesis import settings

    settings.register_profile("ci", derandomize=True, deadline=None,
                              max_examples=20)
    settings.register_profile("dev", deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:  # hypothesis is optional (tests importorskip it)
    pass
