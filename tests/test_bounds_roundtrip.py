"""core.bounds inversion round-trips (ISSUE 9 satellite).

Two inverse pairs, both measures, several kernels:

  * Theorem 12 covering bound: ``required_d(eps, delta) = D`` implies
    ``eps_at(D, delta) <= eps`` — buying the demanded budget always buys
    back a guarantee at least as tight as requested;
  * fixed-pair union bound: ``required_features_for_pairs`` vs
    ``pairwise_eps``, exactly invertible in closed form.

Plus the anti-drift pin: ``obs.drift.hoeffding_eps`` must equal
``core.bounds.pairwise_eps`` BIT-EXACTLY — the DriftMonitor's live
envelope and the offline acceptance suite share one formula now
(previously duplicated arithmetic; this test keeps it that way).

Deterministic sweep always runs; the hypothesis driver (derandomized ci
profile) widens the same parameter space in CI.
"""
import math

import pytest

from repro.core import (
    ExponentialDotProductKernel,
    HomogeneousPolynomialKernel,
    PolynomialKernel,
)
from repro.core.bounds import (
    constants_for,
    pairwise_eps,
    required_features_for_pairs,
    uniform_failure_prob,
)
from repro.obs.drift import hoeffding_eps

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

KERNELS = {
    "exp": ExponentialDotProductKernel(1.0),
    "poly": PolynomialKernel(degree=3, r=1.0),
    "homog": HomogeneousPolynomialKernel(degree=2),
}
MEASURES = ("geometric", "proportional")


def check_covering_roundtrip(kernel, radius, dim, eps, delta, measure):
    consts = constants_for(kernel, radius, dim)
    d_req = consts.required_d(eps, delta, measure)
    assert d_req >= 1
    eps_back = consts.eps_at(d_req, delta, measure)
    assert 0.0 < eps_back <= eps * (1.0 + 1e-9), (
        f"round-trip loosened the guarantee: required_d({eps})={d_req} "
        f"but eps_at({d_req})={eps_back}")
    # and the inverse is honest: materially fewer features can't still
    # certify eps (ceil slack aside)
    if d_req > 8:
        assert consts.eps_at(d_req // 2, delta, measure) > eps


def check_pairwise_roundtrip(kernel, radius, dim, eps, n_pairs, delta,
                             measure):
    d_req = required_features_for_pairs(kernel, radius, dim, eps, n_pairs,
                                        delta, measure=measure)
    assert d_req >= 1
    back = pairwise_eps(kernel, radius, dim, d_req, n_pairs, delta,
                        measure=measure)
    assert back <= eps * (1.0 + 1e-12)
    # exact closed-form inverse: one feature fewer breaks the guarantee
    if d_req > 1:
        assert pairwise_eps(kernel, radius, dim, d_req - 1, n_pairs,
                            delta, measure=measure) > eps * (1.0 - 1e-12)


@pytest.mark.parametrize("measure", MEASURES)
@pytest.mark.parametrize("kname", sorted(KERNELS))
@pytest.mark.parametrize("eps,delta", [(0.1, 0.05), (0.05, 0.01),
                                       (0.3, 0.2)])
def test_sweep_covering_roundtrip(kname, measure, eps, delta):
    check_covering_roundtrip(KERNELS[kname], 0.5, 8, eps, delta, measure)


@pytest.mark.parametrize("measure", MEASURES)
@pytest.mark.parametrize("kname", sorted(KERNELS))
@pytest.mark.parametrize("eps,n_pairs", [(0.1, 136), (0.02, 10),
                                         (0.5, 1000)])
def test_sweep_pairwise_roundtrip(kname, measure, eps, n_pairs):
    check_pairwise_roundtrip(KERNELS[kname], 0.5, 8, eps, n_pairs, 0.05,
                             measure)


@pytest.mark.parametrize("measure", MEASURES)
@pytest.mark.parametrize("kname", sorted(KERNELS))
@pytest.mark.parametrize("eps", [1e-3, 0.1, 1.0, 10.0, 100.0, 1e6])
@pytest.mark.parametrize("delta", [1e-6, 0.05, 0.99])
def test_uniform_failure_prob_roundtrip(kname, measure, eps, delta):
    """Regression pin (ISSUE 10): required_d and uniform_failure_prob share
    ONE covering-ratio floor, so buying the demanded budget always drives
    the uniform failure probability down to delta — including large eps,
    where the floors previously disagreed (2.0 vs 1e-9), and huge D, where
    float slop in the ceil previously left the probability a few ulps above
    delta."""
    consts = constants_for(KERNELS[kname], 0.5, 8)
    d_req = consts.required_d(eps, delta, measure)
    assert d_req >= 1
    assert uniform_failure_prob(consts, d_req, eps, measure) <= delta


def test_pair_bounds_validate_arguments():
    """Regression pins (ISSUE 10): the pair-bound APIs reject invalid
    inputs with errors naming the offending argument, instead of a bare
    ``math domain error`` (n_pairs=0) or a D=0 budget (huge eps)."""
    k = KERNELS["exp"]
    with pytest.raises(ValueError, match="n_pairs"):
        pairwise_eps(k, 0.5, 8, 128, 0, 0.05)
    with pytest.raises(ValueError, match="n_pairs"):
        required_features_for_pairs(k, 0.5, 8, 0.1, 0, 0.05)
    for bad_delta in (0.0, 1.0, 1.5, -0.1):
        with pytest.raises(ValueError, match="delta"):
            pairwise_eps(k, 0.5, 8, 128, 10, bad_delta)
        with pytest.raises(ValueError, match="delta"):
            required_features_for_pairs(k, 0.5, 8, 0.1, 10, bad_delta)
        with pytest.raises(ValueError, match="delta"):
            constants_for(k, 0.5, 8).required_d(0.1, bad_delta)
    with pytest.raises(ValueError, match="eps"):
        required_features_for_pairs(k, 0.5, 8, -1.0, 10, 0.05)
    with pytest.raises(ValueError, match="eps"):
        constants_for(k, 0.5, 8).required_d(0.0, 0.05)
    with pytest.raises(ValueError, match="num_features"):
        pairwise_eps(k, 0.5, 8, 0, 10, 0.05)
    # huge eps: the raw formula rounds to D=0; the API clamps to >= 1
    assert required_features_for_pairs(k, 0.5, 8, 1e9, 10, 0.05) == 1


def test_eps_at_monotone_in_budget():
    consts = constants_for(KERNELS["exp"], 0.5, 8)
    eps = [consts.eps_at(d, 0.05) for d in (64, 256, 1024, 4096)]
    assert eps == sorted(eps, reverse=True)
    assert all(e > 0 for e in eps)


def test_eps_at_rejects_nonpositive_budget():
    consts = constants_for(KERNELS["exp"], 0.5, 8)
    with pytest.raises(ValueError, match="num_features"):
        consts.eps_at(0, 0.05)


def test_drift_monitor_delegates_to_core_bounds():
    """The anti-drift pin: obs.drift.hoeffding_eps IS
    core.bounds.pairwise_eps — bit-equal for both measures, so the online
    monitor and the offline bound suite cannot diverge again."""
    k = KERNELS["exp"]
    for measure in MEASURES:
        for d in (128, 1024):
            a = hoeffding_eps(k, 0.9, 16, d, 136, 0.05, measure=measure)
            b = pairwise_eps(k, 0.9, 16, d, 136, 0.05, measure=measure)
            assert a == b
    # and the formula is the documented one
    c = constants_for(k, 0.9, 16).c_proportional
    want = math.sqrt(8.0 * c * c * math.log(2.0 * 136 / 0.05) / 1024)
    assert hoeffding_eps(k, 0.9, 16, 1024, 136, 0.05) == pytest.approx(
        want, rel=1e-12)


if HAS_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(eps=st.floats(0.01, 0.9), delta=st.floats(1e-4, 0.5),
           radius=st.floats(0.1, 0.7), dim=st.integers(2, 64),
           kname=st.sampled_from(sorted(KERNELS)),
           measure=st.sampled_from(MEASURES))
    def test_hyp_covering_roundtrip(eps, delta, radius, dim, kname,
                                    measure):
        check_covering_roundtrip(KERNELS[kname], radius, dim, eps, delta,
                                 measure)

    @settings(max_examples=40, deadline=None)
    @given(eps=st.floats(0.01, 0.9), delta=st.floats(1e-4, 0.5),
           n_pairs=st.integers(1, 10_000), dim=st.integers(2, 64),
           kname=st.sampled_from(sorted(KERNELS)),
           measure=st.sampled_from(MEASURES))
    def test_hyp_pairwise_roundtrip(eps, delta, n_pairs, dim, kname,
                                    measure):
        check_pairwise_roundtrip(KERNELS[kname], 0.5, dim, eps, n_pairs,
                                 delta, measure)
