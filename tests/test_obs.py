"""Unit coverage for the repro.obs layer: metrics percentiles, JSONL trace
round-trips, Chrome export, the no-op fast path, provenance stamps, and the
(eps, delta) drift monitor firing exactly when it should."""
import json

import numpy as np
import pytest

from repro.obs import (
    NOOP,
    DriftMonitor,
    MetricsRegistry,
    Obs,
    Tracer,
    chrome_trace,
    clock,
    current_tracer,
    hoeffding_eps,
    install_tracer,
    read_trace,
    resolve,
)
from repro.obs.metrics import percentile

PROV = {"backend": "test", "device_kind": "test", "device_count": 1,
        "interpret": False, "jax_version": "0"}


# ---------------------------------------------------------------------------
# clock
# ---------------------------------------------------------------------------
def test_fake_clock_is_deterministic():
    fc = clock.FakeClock(start=10.0, step=0.5)
    assert [fc(), fc()] == [10.0, 10.5]
    fc.advance(4.0)
    assert fc() == 15.0


def test_real_clock_monotonic():
    a, b = clock.monotonic(), clock.monotonic()
    assert b >= a


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------
def test_percentiles_exact_on_small_sets():
    vals = sorted(float(v) for v in range(101))  # 0..100
    assert percentile(vals, 50.0) == 50.0
    assert percentile(vals, 99.0) == 99.0
    assert percentile([], 50.0) == 0.0
    assert percentile([7.0], 90.0) == 7.0


def test_histogram_summary_and_snapshot():
    reg = MetricsRegistry(now=clock.FakeClock())
    for v in (1.0, 2.0, 3.0, 4.0):
        reg.histogram("serve/ttft_s").observe(v)
    reg.counter("serve/requests_submitted").inc(3)
    reg.gauge("serve/queue_depth").set(2)

    snap = reg.snapshot(provenance=PROV)
    assert snap["schema"] == "repro.obs.metrics/v1"
    assert snap["provenance"] == PROV
    assert snap["counters"]["serve/requests_submitted"] == 3.0
    assert snap["gauges"]["serve/queue_depth"] == 2.0
    h = snap["histograms"]["serve/ttft_s"]
    assert h["count"] == 4 and h["mean"] == 2.5
    assert h["min"] == 1.0 and h["max"] == 4.0
    assert h["p50"] == 3.0  # nearest-rank on [1,2,3,4]
    # JSON-able end to end
    json.dumps(snap)


def test_histogram_reservoir_keeps_exact_count():
    from repro.obs import metrics as m

    reg = MetricsRegistry(now=clock.FakeClock())
    h = reg.histogram("x")
    n = m._RESERVOIR + 500
    for v in range(n):
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == n and s["min"] == 0.0 and s["max"] == n - 1
    assert len(h._vals) == m._RESERVOIR


def test_write_json(tmp_path):
    reg = MetricsRegistry(now=clock.FakeClock())
    reg.counter("c").inc()
    p = reg.write_json(tmp_path / "m.json", provenance=PROV)
    assert json.loads(p.read_text())["counters"]["c"] == 1.0


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------
def test_tracer_jsonl_roundtrip(tmp_path):
    path = tmp_path / "t.jsonl"
    tr = Tracer(path=path, now=clock.FakeClock(), provenance=PROV)
    tr.event("request/submit", request_id=0)
    with tr.span("prefill", bucket=32):
        pass
    tr.close()

    recs = read_trace(path)
    assert recs == tr.records
    assert recs[0]["type"] == "meta"
    assert recs[0]["schema"] == "repro.obs.trace/v1"
    assert recs[0]["provenance"] == PROV
    (ev,) = [r for r in recs if r["type"] == "event"]
    assert ev["name"] == "request/submit" and ev["attrs"]["request_id"] == 0
    (sp,) = [r for r in recs if r["type"] == "span"]
    # FakeClock(step=1): event reads t=0 -> ts 0us? meta takes no read;
    # event read 0.0, span start 1.0, span end 2.0
    assert sp["ts_us"] == 1e6 and sp["dur_us"] == 1e6
    assert sp["attrs"] == {"bucket": 32}


def test_tracer_span_records_on_exception():
    tr = Tracer(now=clock.FakeClock(), provenance=PROV)
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("x")
    assert len(tr.spans("boom")) == 1


def test_chrome_trace_shapes():
    tr = Tracer(now=clock.FakeClock(), provenance=PROV)
    tr.event("e")
    with tr.span("s"):
        pass
    chrome = chrome_trace(tr.records)
    phases = [e["ph"] for e in chrome["traceEvents"]]
    assert phases == ["M", "i", "X"]
    assert all("ts" in e for e in chrome["traceEvents"][1:])


def test_ambient_tracer_install_restore():
    assert current_tracer() is None
    tr = Tracer(now=clock.FakeClock(), provenance=PROV)
    prev = install_tracer(tr)
    try:
        assert prev is None and current_tracer() is tr
    finally:
        install_tracer(prev)
    assert current_tracer() is None


def test_kernel_scope_records_span_with_analytic_cost():
    import jax
    import jax.numpy as jnp

    from repro.obs import kernel_scope

    x = jnp.ones((4, 8), jnp.float32)
    # no tracer: pure named_scope, no records anywhere
    with kernel_scope("rm_feature", x=x):
        pass

    tr = Tracer(now=clock.FakeClock(), provenance=PROV)
    prev = install_tracer(tr)
    try:
        with kernel_scope("rm_feature", x=x,
                          cost=dict(batch=4, d=8, depth=3, f=16)):
            pass
    finally:
        install_tracer(prev)
    (sp,) = tr.spans("kernel/rm_feature")
    assert sp["attrs"]["traced"] is False
    assert sp["attrs"]["flops"] > 0 and sp["attrs"]["hbm_bytes"] > 0


def test_fused_wrapper_emits_kernel_span():
    """estimate_gram(use_pallas=True) runs the rm_feature fused wrapper,
    which must contribute a kernel/rm_feature span with launch costs when a
    tracer is ambient — and nothing when none is installed."""
    import jax

    from repro.core import ExponentialDotProductKernel, make_feature_map

    fm = make_feature_map(ExponentialDotProductKernel(), 4, 16,
                          jax.random.PRNGKey(0))
    X = np.random.default_rng(0).standard_normal((4, 4)).astype(np.float32)
    X *= 0.2

    G0 = np.asarray(fm.estimate_gram(X, use_pallas=True))
    tr = Tracer(now=clock.FakeClock(), provenance=PROV)
    prev = install_tracer(tr)
    try:
        G1 = np.asarray(fm.estimate_gram(X, use_pallas=True))
    finally:
        install_tracer(prev)
    np.testing.assert_array_equal(G0, G1)  # tracing never changes values
    spans = tr.spans("kernel/rm_feature")
    assert spans and spans[0]["attrs"]["flops"] > 0


# ---------------------------------------------------------------------------
# facade / no-op path
# ---------------------------------------------------------------------------
def test_resolve_none_is_shared_noop():
    assert resolve(None) is NOOP
    obs = Obs(clock=clock.FakeClock(), provenance=PROV)
    assert resolve(obs) is obs
    obs.close()


def test_noop_is_inert():
    assert NOOP.enabled is False
    NOOP.event("x", a=1)
    NOOP.counter("c")
    NOOP.gauge("g", 1.0)
    NOOP.histogram("h", 1.0)
    NOOP.tick_drift()
    with NOOP.span("s", a=1):
        pass
    assert NOOP.span("a") is NOOP.span("b")  # shared null context
    assert NOOP.now() <= NOOP.now()


def test_obs_shares_one_clock():
    fc = clock.FakeClock()
    obs = Obs(clock=fc, provenance=PROV)
    t0 = obs.now()
    obs.histogram("h", 1.0)          # one clock read inside observe
    with obs.span("s"):
        pass                         # two reads
    t1 = obs.now()
    assert t1 - t0 == 4.0            # every read came off the same clock
    obs.close()


def test_obs_installs_and_restores_kernel_tracer():
    obs = Obs(clock=clock.FakeClock(), provenance=PROV,
              install_kernel_tracing=True)
    assert current_tracer() is obs.tracer
    obs.close()
    assert current_tracer() is None


# ---------------------------------------------------------------------------
# drift monitoring
# ---------------------------------------------------------------------------
def _monitor(num_features, **kwargs):
    import jax

    from repro.core import ExponentialDotProductKernel

    return DriftMonitor.for_estimator(
        ExponentialDotProductKernel(), 8, num_features,
        estimator="rm", seed=0, **kwargs)


def test_drift_silent_at_bound_satisfying_budget():
    """At a healthy D the observed sup error sits inside eps(D, delta)."""
    mon = _monitor(2048, n_sentinels=8)
    report = mon.check()
    assert report.ok, (report.sup_err, report.eps_bound)
    assert mon.checks == 1 and mon.violations == 0


def test_drift_fires_on_under_budget_features():
    """A drifted/under-provisioned map must trip the monitor: judge a
    small-D map against the (tight) envelope a healthy budget would owe.
    ``margin`` scales the bound the deployment claims to meet."""
    mon = _monitor(8, n_sentinels=8, margin=0.01)
    report = mon.check()
    assert not report.ok
    assert mon.violations == 1
    assert report.sup_err > 0.01 * report.eps_bound


def test_drift_bound_shrinks_with_budget():
    e_small = _monitor(64).eps_bound()
    e_big = _monitor(4096).eps_bound()
    assert e_big < e_small
    # hoeffding core scales as 1/sqrt(D)
    h_small = hoeffding_eps(_monitor(64).kernel, 0.9, 8, 64, 10, 0.05)
    h_big = hoeffding_eps(_monitor(64).kernel, 0.9, 8, 256, 10, 0.05)
    assert h_small / h_big == pytest.approx(2.0)


def test_drift_ingest_keeps_reservoir_in_ball():
    mon = _monitor(256, n_sentinels=8)
    mon.ingest(np.full((32, 8), 10.0))  # way outside the ball
    norms = np.linalg.norm(mon._sentinels, axis=1)
    assert np.all(norms <= mon.radius + 1e-5)
    assert mon._sentinels.shape == (8, 8)


def test_obs_tick_drift_emits_metrics_and_violation_event():
    mon = _monitor(8, n_sentinels=8, margin=0.01)
    obs = Obs(clock=clock.FakeClock(), provenance=PROV,
              drift=mon, drift_every=2)
    obs.tick_drift()                      # tick 1: no check yet
    assert mon.checks == 0
    obs.tick_drift()                      # tick 2: check runs, violates
    assert mon.checks == 1 and mon.violations == 1
    snap = obs.metrics.snapshot(provenance=PROV)
    assert snap["counters"]["drift/violations"] == 1.0
    assert snap["gauges"]["drift/sup_err"] > 0
    assert obs.tracer.events("drift/violation")
    assert obs.tracer.spans("drift/check")
    obs.close()


# ---------------------------------------------------------------------------
# provenance stamps
# ---------------------------------------------------------------------------
def test_platform_provenance_shape():
    from repro.common.env import platform_provenance

    prov = platform_provenance()
    for key in ("backend", "device_kind", "device_count", "interpret",
                "jax_version"):
        assert key in prov
    assert isinstance(prov["interpret"], bool)


def test_default_snapshots_are_provenance_stamped():
    reg = MetricsRegistry(now=clock.FakeClock())
    assert "backend" in reg.snapshot()["provenance"]
    tr = Tracer(now=clock.FakeClock())
    assert "backend" in tr.records[0]["provenance"]


# ---------------------------------------------------------------------------
# CLI + trace checker
# ---------------------------------------------------------------------------
def _write_serve_like_trace(path):
    tr = Tracer(path=path, now=clock.FakeClock(), provenance=PROV)
    tr.event("request/submit", request_id=0)
    tr.event("request/admit", request_id=0, slot=0, bucket=32)
    with tr.span("prefill", bucket=32):
        pass
    with tr.span("decode/step", active=1):
        pass
    tr.event("request/finish", request_id=0, tokens=4)
    tr.close()
    return tr


def test_check_trace_accepts_valid_and_rejects_broken(tmp_path):
    import sys

    sys.path.insert(0, "tools")
    try:
        from check_trace import check_trace
    finally:
        sys.path.pop(0)

    good = tmp_path / "good.jsonl"
    _write_serve_like_trace(good)
    assert check_trace(good) == []

    # missing lifecycle records
    bad = tmp_path / "bad.jsonl"
    tr = Tracer(path=bad, now=clock.FakeClock(), provenance=PROV)
    tr.event("request/submit", request_id=0)
    tr.close()
    errs = check_trace(bad)
    assert any("prefill" in e for e in errs)
    assert any("request/finish" in e for e in errs)

    # meta header missing
    headless = tmp_path / "headless.jsonl"
    headless.write_text('{"type": "event", "name": "x", "ts_us": 0.0}\n')
    assert any("meta" in e for e in check_trace(headless))


def test_obs_cli_summarize_and_chrome(tmp_path, capsys):
    from repro.obs.__main__ import main

    path = tmp_path / "t.jsonl"
    _write_serve_like_trace(path)
    assert main(["summarize", str(path)]) == 0
    out = capsys.readouterr().out
    assert "decode/step" in out and "prefill" in out

    chrome_out = tmp_path / "t.chrome.json"
    assert main(["chrome", str(path), "-o", str(chrome_out)]) == 0
    data = json.loads(chrome_out.read_text())
    assert any(e["ph"] == "X" for e in data["traceEvents"])


def test_bench_check_warns_on_interpret_cpu_artifact(tmp_path, capsys):
    from repro.bench.__main__ import _warn_if_interpret_cpu

    path = tmp_path / "BENCH_x.json"
    path.write_text(json.dumps({
        "provenance": {"backend": "cpu", "interpret": True},
    }))
    _warn_if_interpret_cpu(str(path))
    assert "INTERPRET" in capsys.readouterr().out

    path.write_text(json.dumps({
        "provenance": {"backend": "tpu", "interpret": False},
    }))
    _warn_if_interpret_cpu(str(path))
    assert capsys.readouterr().out == ""
