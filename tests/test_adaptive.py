"""The adaptive-accuracy subsystem end to end (docs/adaptive.md).

Four surfaces, one contract:

* ``select_budget`` — the (eps, delta) -> (estimator, D, precision)
  decision always CERTIFIES the accuracy target (``eps_at(D, delta) <=
  eps`` for every kernel in the grid) and prices candidates from the
  ``CostModel`` honestly (latency budget is a preference, accuracy a
  guarantee);
* ``make_feature_map(eps=..., delta=...)`` — the accuracy-target
  constructor mode sizes D from the same inversion;
* the drift -> grow control loop — ``DriftMonitor.recommend()`` fires
  exactly on violations, ``GrowableFeatureMap.grow()`` + ``rebind``
  tighten the envelope, and the whole loop is deterministic under
  ``FakeClock``;
* serving tiers — the Scheduler maps per-request tier names to feature
  generations through ``StepExecutor.tier_features``.
"""
import json

import jax
import numpy as np
import pytest

from repro.core import (
    CostModel,
    ExponentialDotProductKernel,
    PolynomialKernel,
    make_feature_map,
    make_growable_feature_map,
    select_budget,
)
from repro.core.bounds import constants_for
from repro.core.select import main as select_main
from repro.core.select import relative_to_additive_eps, selection_section

KERNELS = [ExponentialDotProductKernel(1.0), PolynomialKernel(3, 1.0),
           PolynomialKernel(7, 0.5)]


def _payload():
    """A minimal two-shape bench payload the CostModel can fit."""
    return {
        "schema_version": 2,
        "backend": "cpu",
        "interpret": True,
        "results": {
            "s1": {"kernel": "exp", "d": 16, "F": 128, "batch": 64,
                   "cells": {
                       "rm/fp32": {"fused_feats_per_s": 1e7},
                       "rm/bf16": {"fused_feats_per_s": 2e7},
                       "ctr/fp32": {"fused_feats_per_s": 5e6},
                   }},
            "s2": {"kernel": "exp", "d": 16, "F": 512, "batch": 64,
                   "cells": {
                       "rm/fp32": {"fused_feats_per_s": 4e7},
                       "rm/bf16": {"fused_feats_per_s": 2e7},
                       "ctr/fp32": {"fused_feats_per_s": 5e6},
                   }},
        },
    }


# ---------------------------------------------------------------------------
# CostModel
# ---------------------------------------------------------------------------
def test_cost_model_rows_and_coverage():
    cm = CostModel.from_payload(_payload())
    assert cm.covers("rm", "fp32") and cm.covers("ctr", "fp32")
    assert not cm.covers("tensor_sketch", "fp32")
    assert cm.missing_cells(["rm", "tensor_sketch"], ["fp32", "bf16"]) == [
        "tensor_sketch/fp32", "tensor_sketch/bf16"]
    # log-F interpolation: between the benched Fs, strictly between the
    # benched throughputs; outside, clamped to the nearest measurement
    t128 = cm.throughput("rm", "fp32", 128)
    t512 = cm.throughput("rm", "fp32", 512)
    tmid = cm.throughput("rm", "fp32", 256)
    assert t128 == pytest.approx(1e7) and t512 == pytest.approx(4e7)
    assert t128 < tmid < t512
    assert cm.throughput("rm", "fp32", 8) == pytest.approx(t128)
    assert cm.throughput("rm", "fp32", 10**6) == pytest.approx(t512)
    # latency = batch * F / throughput
    assert cm.predict_latency_s("rm", "fp32", 128, 64) == pytest.approx(
        64 * 128 / 1e7)
    with pytest.raises(KeyError, match="tensor_sketch/fp32"):
        cm.throughput("tensor_sketch", "fp32", 128)


# ---------------------------------------------------------------------------
# select_budget: the accuracy guarantee
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kernel", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("eps,delta", [(0.5, 0.05), (0.1, 0.01),
                                       (2.0, 0.5)])
def test_decision_certifies_target(kernel, eps, delta):
    dec = select_budget(kernel, 12, eps, delta, measure="proportional",
                        radius=0.8)
    consts = constants_for(kernel, 0.8, 12, 2.0)
    assert dec.eps_certified <= eps
    assert consts.eps_at(dec.num_features, delta,
                         "proportional") <= eps
    assert dec.eps_certified == pytest.approx(
        consts.eps_at(dec.num_features, delta, "proportional"))


def test_latency_ranking_and_budget_flag():
    cm = CostModel.from_payload(_payload())
    kern = ExponentialDotProductKernel(1.0)
    # free choice: the fastest PRICED candidate wins (rm/bf16 at small F
    # ... but D here is large, so rank at the selected D)
    dec = select_budget(kern, 16, 1.0, 0.1, cost_model=cm,
                        measure="proportional", radius=0.7, batch=64)
    priced = [c for c in dec.candidates
              if c["predicted_latency_s"] is not None]
    assert dec.predicted_latency_s == min(c["predicted_latency_s"]
                                          for c in priced)
    # an impossible latency budget: fastest still returned, flagged False
    tight = select_budget(kern, 16, 1.0, 0.1, cost_model=cm,
                          measure="proportional", radius=0.7, batch=64,
                          latency_budget_s=1e-12)
    assert tight.meets_latency_budget is False
    assert tight.num_features == dec.num_features  # accuracy unmoved
    # a generous budget: flagged True
    loose = select_budget(kern, 16, 1.0, 0.1, cost_model=cm,
                          measure="proportional", radius=0.7, batch=64,
                          latency_budget_s=1e9)
    assert loose.meets_latency_budget is True


def test_estimator_pin_and_platform_guard():
    cm = CostModel.from_payload(_payload())
    dec = select_budget(ExponentialDotProductKernel(1.0), 16, 1.0, 0.1,
                        estimator="ctr", cost_model=cm,
                        measure="proportional", radius=0.7)
    assert dec.estimator == "ctr"
    assert {c["estimator"] for c in dec.candidates} == {"ctr"}
    with pytest.raises(KeyError, match="unknown"):
        select_budget(ExponentialDotProductKernel(1.0), 16, 1.0, 0.1,
                      estimator="nope")
    with pytest.raises(ValueError, match="platform"):
        select_budget(ExponentialDotProductKernel(1.0), 16, 1.0, 0.1,
                      cost_model=cm, platform="tpu")
    # matching platform passes
    ok = select_budget(ExponentialDotProductKernel(1.0), 16, 1.0, 0.1,
                       cost_model=cm, platform="cpu",
                       measure="proportional", radius=0.7)
    assert ok.backend == "cpu"


def test_relative_mode():
    kern = ExponentialDotProductKernel(1.0)
    # min |f| on [-R^2, R^2] for exp is exp(-R^2)
    eps_abs = relative_to_additive_eps(kern, 0.8, 0.5)
    assert eps_abs == pytest.approx(0.5 * np.exp(-0.64), rel=1e-3)
    dec = select_budget(kern, 8, 0.5, 0.1, relative=True, radius=0.8,
                        measure="proportional")
    assert dec.eps == pytest.approx(eps_abs, rel=1e-3)
    assert dec.eps_certified <= dec.eps
    # odd polynomial crosses zero on the ball -> loud failure
    with pytest.raises(ValueError, match="relative"):
        relative_to_additive_eps(PolynomialKernel(3, 0.0), 1.0, 0.5)


def test_selection_section_certifies_every_shape(tmp_path):
    payload = _payload()
    sec = selection_section(payload, targets=[(0.5, 0.1)])
    assert set(sec["decisions"]) == {"s1", "s2"}
    for decs in sec["decisions"].values():
        (dec,) = decs
        assert dec["eps_certified"] <= dec["eps"]
        assert dec["predicted_latency_s"] is not None


def test_select_cli(tmp_path, capsys):
    bench = tmp_path / "bench.json"
    bench.write_text(json.dumps(_payload()))
    rc = select_main(["--kernel", "exp", "--dim", "16", "--eps", "1.0",
                      "--delta", "0.1", "--bench", str(bench)])
    assert rc == 0
    out = json.loads(capsys.readouterr().out)
    assert out["eps_certified"] <= out["eps"]
    # coverage gate: the toy payload misses cells -> exit 1
    rc = select_main(["--bench", str(bench), "--check-coverage"])
    assert rc == 1
    assert "missing" in capsys.readouterr().out
    # no artifact at all under --check-coverage -> exit 1
    rc = select_main(["--bench", str(tmp_path / "none.json"),
                      "--check-coverage"])
    assert rc == 1


# ---------------------------------------------------------------------------
# make_feature_map accuracy-target mode
# ---------------------------------------------------------------------------
def test_make_feature_map_eps_mode():
    kern = ExponentialDotProductKernel(1.0)
    fm = make_feature_map(kern, 6, key=jax.random.PRNGKey(0), eps=1.5,
                          delta=0.2, radius=0.7, measure="proportional")
    consts = constants_for(kern, 0.7, 6, 2.0)
    d_req = consts.required_d(1.5, 0.2, "proportional")
    # eps mode IS num_features mode at the Theorem 12 inversion: the two
    # constructors produce identical plans
    ref = make_feature_map(kern, 6, d_req, jax.random.PRNGKey(0),
                           radius=0.7, measure="proportional")
    assert fm.plan == ref.plan
    assert fm.output_dim == ref.output_dim
    with pytest.raises(ValueError, match="delta"):
        make_feature_map(kern, 6, key=jax.random.PRNGKey(0), eps=0.5)
    with pytest.raises(ValueError, match="num_features"):
        make_feature_map(kern, 6, 64, jax.random.PRNGKey(0), eps=0.5,
                         delta=0.1)
    with pytest.raises(ValueError, match="num_features"):
        make_feature_map(kern, 6, key=jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# the drift -> grow loop, deterministic under FakeClock
# ---------------------------------------------------------------------------
def test_drift_recommend_fires_only_on_violation():
    from repro.obs.drift import DriftMonitor

    kern = ExponentialDotProductKernel(1.0)
    gm = make_growable_feature_map(kern, 6, jax.random.PRNGKey(0),
                                   base_features=64,
                                   measure="proportional")
    mon = DriftMonitor(gm, kern, delta=0.05, radius=0.7,
                       measure="proportional")
    assert mon.recommend() is None          # no check yet
    rep = mon.check()
    if rep.ok:
        assert mon.recommend() is None      # in-envelope -> no growth
    # force a violation deterministically: a margin far below any real
    # error makes the SAME report a violation without touching the map
    mon_tight = DriftMonitor(gm, kern, delta=0.05, radius=0.7,
                             measure="proportional", margin=1e-9)
    rep = mon_tight.check()
    assert not rep.ok
    rec = mon_tight.recommend()
    assert rec is not None
    assert rec.num_features_target == 2 * gm.output_dim
    assert rec.eps_bound_target < rec.eps_bound_now
    assert str(gm.output_dim) in rec.reason


def test_drift_grow_rebind_loop_deterministic():
    """The full control loop under FakeClock: violation -> recommend ->
    grow -> rebind -> the envelope tightens by 1/sqrt(2) per doubling and
    two identical runs produce identical trajectories."""
    from repro.obs import Obs, clock
    from repro.obs.drift import DriftMonitor

    def run():
        kern = ExponentialDotProductKernel(1.0)
        gm = make_growable_feature_map(kern, 6, jax.random.PRNGKey(0),
                                       base_features=48,
                                       measure="proportional")
        mon = DriftMonitor(gm, kern, delta=0.05, radius=0.7,
                           measure="proportional", margin=1e-9)
        obs = Obs(clock=clock.FakeClock(step=0.5), drift=mon,
                  drift_every=1)
        budgets, bounds = [], []
        for _ in range(3):
            obs.tick_drift()
            rec = mon.recommend()
            assert rec is not None          # margin guarantees violation
            gm = gm.grow_to(rec.num_features_target)
            mon.rebind(gm)
            budgets.append(gm.output_dim)
            bounds.append(rec.eps_bound_target)
        obs.close()
        return budgets, bounds, mon.checks, mon.violations

    a = run()
    b = run()
    assert a == b                            # FakeClock determinism
    budgets, bounds, checks, violations = a
    assert budgets == sorted(budgets)
    assert budgets[0] < budgets[1] < budgets[2]   # geometric escalation
    assert bounds[0] > bounds[1] > bounds[2]      # envelope tightens
    assert checks == 3 and violations == 3
    # rebind drops the stale report: recommend() can't re-fire pre-check
    kern = ExponentialDotProductKernel(1.0)
    gm = make_growable_feature_map(kern, 6, jax.random.PRNGKey(0),
                                   base_features=48,
                                   measure="proportional")
    mon = DriftMonitor(gm, kern, margin=1e-9, radius=0.7,
                       measure="proportional")
    mon.check()
    assert mon.recommend() is not None
    mon.rebind(gm.grow())
    assert mon.recommend() is None


def test_obs_emits_grow_recommendation_event(tmp_path):
    from repro.obs import Obs, clock
    from repro.obs.drift import DriftMonitor

    kern = ExponentialDotProductKernel(1.0)
    gm = make_growable_feature_map(kern, 6, jax.random.PRNGKey(0),
                                   base_features=48,
                                   measure="proportional")
    mon = DriftMonitor(gm, kern, margin=1e-9, radius=0.7,
                       measure="proportional")
    path = tmp_path / "trace.jsonl"
    obs = Obs(trace_path=str(path), clock=clock.FakeClock(step=0.5),
              drift=mon, drift_every=1)
    obs.tick_drift()
    obs.close()
    rows = [json.loads(line) for line in path.read_text().splitlines()
            if line.strip()]
    names = [r.get("name") for r in rows]
    assert "drift/violation" in names
    assert "drift/grow_recommendation" in names
    rec_row = next(r for r in rows
                   if r.get("name") == "drift/grow_recommendation")
    assert rec_row["attrs"]["num_features_target"] == 2 * gm.output_dim


# ---------------------------------------------------------------------------
# serving tiers
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiered_scheduler():
    from repro.configs import get_config
    from repro.models import init_model
    from repro.serve import Scheduler

    cfg = get_config("qwen3-1.7b", smoke=True, attention_mode="rm")
    params = init_model(cfg, jax.random.PRNGKey(0))
    return cfg, Scheduler(
        cfg, params, num_slots=2, max_len=64, rng_seed=0,
        accuracy_tiers={"low": 1, "standard": 2, "high": 4})


def test_scheduler_tier_features(tiered_scheduler):
    from repro.serve import Request

    cfg, sched = tiered_scheduler
    per_gen = cfg.rm.num_features // 4
    assert sched.executor.feature_generations == 4
    assert sched.executor.tier_features(1) == per_gen
    assert sched.executor.tier_features(4) == cfg.rm.num_features
    with pytest.raises(ValueError, match="range"):
        sched.executor.tier_features(5)
    prompts = np.arange(6) % cfg.vocab_size
    for i, tier in enumerate(["low", "high", None]):
        sched.submit(Request(request_id=i, prompt=prompts,
                             max_new_tokens=2, accuracy_tier=tier))
    done = sched.run()
    assert done[0].tier_features == per_gen
    assert done[1].tier_features == cfg.rm.num_features
    assert done[2].tier_features is None     # untiered -> full budget


def test_scheduler_rejects_bad_tiers(tiered_scheduler):
    from repro.serve import Request

    cfg, sched = tiered_scheduler
    prompt = np.arange(4) % cfg.vocab_size
    with pytest.raises(ValueError, match="gold"):
        sched.submit(Request(request_id=99, prompt=prompt,
                             accuracy_tier="gold"))


def test_executor_tier_validation():
    import dataclasses

    from repro.configs import get_config
    from repro.models import init_model
    from repro.serve import Scheduler
    from repro.serve.executor import StepExecutor

    cfg = get_config("qwen3-1.7b", smoke=True, attention_mode="rm")
    params = init_model(cfg, jax.random.PRNGKey(0))
    # generations must divide the budget
    bad = cfg.rm.num_features + 1
    with pytest.raises(ValueError, match="divide"):
        StepExecutor(cfg, params, 1, 32, feature_generations=bad)
    # tiers need an RM feature budget
    exact = dataclasses.replace(cfg, attention_mode="exact").validate()
    params_exact = init_model(exact, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="rm|RM"):
        StepExecutor(exact, params_exact, 1, 32, feature_generations=2)
    with pytest.raises(ValueError, match=">= 1"):
        Scheduler(cfg, params, num_slots=1, max_len=32,
                  accuracy_tiers={"bad": 0})
