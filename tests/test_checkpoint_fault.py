"""Checkpoint manager + fault tolerance tests (atomicity, keep-k, restarts,
elastic resharding)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager
from repro.train.fault import StragglerMonitor, elastic_remesh, run_with_restarts


def _state(v=0.0):
    return {
        "params": {"w": jnp.full((4, 8), v), "b": jnp.zeros((8,))},
        "step": jnp.asarray(int(v), jnp.int32),
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(10, _state(1.0))
    out = mgr.restore()
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]), 1.0)
    assert int(out["step"]) == 1


def test_checkpoint_keep_k_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(float(s)))
    assert mgr.available_steps() == [3, 4]


def test_checkpoint_structure_mismatch_detected(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _state())
    bad_template = {"params": {"w": jnp.zeros((4, 8))}, "extra": jnp.zeros(())}
    with pytest.raises(ValueError, match="structure mismatch"):
        mgr.restore(template=bad_template)


def test_checkpoint_atomic_publish(tmp_path):
    """A leftover tmp dir never shadows a valid checkpoint."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, _state(5.0))
    # simulate a crashed partial write
    (tmp_path / "tmp.6.999").mkdir()
    assert mgr.latest_step() == 5
    out = mgr.restore()
    assert int(out["step"]) == 5


def test_run_with_restarts_recovers(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    crashed = {"count": 0}

    def step_fn(state, step):
        if step == 7 and crashed["count"] == 0:
            crashed["count"] += 1
            raise RuntimeError("simulated node failure")
        return {**state, "step": jnp.asarray(step + 1, jnp.int32),
                "params": state["params"]}

    final = run_with_restarts(step_fn, _state(), num_steps=12,
                              ckpt_manager=mgr, checkpoint_every=5,
                              max_restarts=2)
    assert crashed["count"] == 1
    assert int(final["step"]) == 12


def test_run_with_restarts_gives_up(tmp_path):
    mgr = CheckpointManager(tmp_path)

    def always_fail(state, step):
        raise RuntimeError("persistent failure")

    with pytest.raises(RuntimeError, match="persistent"):
        run_with_restarts(always_fail, _state(), 5, mgr, max_restarts=2)


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=2.0, warmup_steps=2)
    flags = [mon.record(i, 0.1) for i in range(8)]
    assert not any(flags)
    assert mon.record(8, 0.5)          # 5x the mean -> flagged
    assert len(mon.events) == 1
    assert mon.events[0]["step"] == 8


def test_elastic_remesh_single_device(tmp_path):
    """Checkpoint written under one topology restores onto another."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(tmp_path)
    mgr.save(3, _state(3.0))

    def make_mesh():
        return jax.make_mesh((1, 1), ("data", "model"))

    def make_shardings(mesh):
        return jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), _state()
        )

    mesh, state = elastic_remesh(mgr, make_mesh, make_shardings)
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]), 3.0)
