"""bf16-vs-fp32 parity suite over the whole estimator registry.

Two contracts (ISSUE 5 satellite):

1. **Parity under documented tolerances** — the bf16 precision policy
   (bf16 inputs/packed weights, fp32 accumulation) may only move features
   and Gram-MSE by the documented per-estimator budgets below (quoted in
   docs/performance.md). Parameter storage in bf16 is LOSSLESS for every
   family (draws take values in {0, +-1}), which is pinned exactly.
2. **fp32 accumulation** — the bf16 path must NOT collapse to bf16
   accumulation. Each fused kernel is driven with an adversarial
   all-ones reduction (4096 terms of 2^-9): fp32 accumulation returns the
   exact sum 8.0; sequential bf16 accumulation stalls at 1.0 (adding
   2^-9 to 1.0 is a half-ulp round-to-even no-op in bf16), an 8x error
   the assertion could not miss.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.dtypes import resolve_precision
from repro.core import ExponentialDotProductKernel, make_feature_map, registry

ESTIMATORS = registry.list_estimators()
KERN = ExponentialDotProductKernel(1.0)

# Documented per-estimator bf16 budgets (docs/performance.md):
#   feature_atol — max |z_bf16 - z_fp32| elementwise on unit-ball inputs;
#   gram_mse_delta — max |MSE_bf16 - MSE_fp32| of the Gram estimate vs the
#   exact kernel. tensor_sketch carries the largest budget: its packed
#   cos/sin tensors round to bf16, where rm/ctr only round x.
TOLERANCES = {
    "rm": {"feature_atol": 5e-3, "gram_mse_delta": 5e-5},
    "ctr": {"feature_atol": 5e-3, "gram_mse_delta": 5e-5},
    "tensor_sketch": {"feature_atol": 2e-2, "gram_mse_delta": 2e-4},
}
_DEFAULT_TOL = {"feature_atol": 2e-2, "gram_mse_delta": 2e-4}


def _build(name, *, d=16, F=192):
    fm = make_feature_map(KERN, d, F, jax.random.PRNGKey(0),
                          estimator=name, measure="proportional")
    x = jax.random.normal(jax.random.PRNGKey(1), (24, d))
    x = x / jnp.linalg.norm(x, axis=1, keepdims=True) * 0.8
    return fm, x


@pytest.mark.parametrize("name", ESTIMATORS)
def test_bf16_feature_parity_under_tolerance(name):
    fm, x = _build(name)
    tol = TOLERANCES.get(name, _DEFAULT_TOL)
    z32 = np.asarray(fm.apply(x, use_pallas=False))
    for use_pallas in (False, True):
        zb = np.asarray(fm.apply(x, use_pallas=use_pallas,
                                 interpret=True, precision="bf16"))
        assert zb.dtype == np.float32          # output stays fp32
        err = np.max(np.abs(zb - z32))
        assert err <= tol["feature_atol"], (name, use_pallas, err)


@pytest.mark.parametrize("name", ESTIMATORS)
def test_bf16_gram_mse_delta_under_tolerance(name):
    fm, x = _build(name)
    tol = TOLERANCES.get(name, _DEFAULT_TOL)
    K = np.asarray(KERN.gram(x))
    mse32 = float(np.mean(
        (np.asarray(fm.estimate_gram(x, use_pallas=False)) - K) ** 2))
    mseb = float(np.mean(
        (np.asarray(fm.estimate_gram(x, use_pallas=True, interpret=True,
                                     precision="bf16")) - K) ** 2))
    assert abs(mseb - mse32) <= tol["gram_mse_delta"], (name, mse32, mseb)


@pytest.mark.parametrize("name", ESTIMATORS)
def test_bf16_param_storage_is_lossless(name):
    """{0, +-1}-valued draws survive bf16 storage bit-exactly."""
    est = registry.get(name)
    plan = est.make_plan(KERN, 10, 96, measure="proportional", seed=0)
    p32 = est.init_params(plan, jax.random.PRNGKey(3))
    pb = est.init_params(plan, jax.random.PRNGKey(3), dtype=jnp.bfloat16)
    for k in p32:
        np.testing.assert_array_equal(
            np.asarray(p32[k], dtype=np.float32),
            np.asarray(pb[k], dtype=np.float32), err_msg=(name, k))


def test_unknown_precision_rejected_with_names():
    with pytest.raises(ValueError, match="fp32"):
        resolve_precision("fp8")


def _bf16_sequential_sum(x):
    """What a collapsed bf16 accumulator would compute for sum(x)."""
    xb = jnp.asarray(x, jnp.bfloat16)

    def body(i, acc):
        return (acc + xb[i]).astype(jnp.bfloat16)

    return float(jax.lax.fori_loop(0, xb.shape[0], body,
                                   jnp.bfloat16(0.0)))


_D = 4096
_VAL = 2.0 ** -9          # exact in bf16
_TRUE = _D * _VAL         # 8.0


def test_adversarial_sum_discriminates_accumulators():
    """Sanity: the probe really separates fp32 from bf16 accumulation."""
    x = np.full((_D,), _VAL, np.float32)
    assert abs(float(np.sum(x)) - _TRUE) < 1e-6
    assert abs(_bf16_sequential_sum(x) - _TRUE) > 0.5 * _TRUE


def test_rm_fused_kernel_accumulates_fp32():
    from repro.kernels.rm_feature.ops import rm_feature_fused

    x = jnp.full((4, _D), _VAL, jnp.bfloat16)
    w = jnp.ones((1, 8, _D), jnp.bfloat16)        # depth-1, all-ones
    deg = jnp.ones((8,), jnp.int32)
    sc = jnp.ones((8,), jnp.float32)
    out = np.asarray(rm_feature_fused(x, w, deg, sc, interpret=True))
    np.testing.assert_allclose(out, _TRUE, rtol=1e-3)


def test_ctr_fused_kernel_accumulates_fp32():
    from repro.kernels.ctr_feature.ops import ctr_feature_fused

    x = jnp.full((4, _D), _VAL, jnp.bfloat16)
    wr = jnp.ones((1, 8, _D), jnp.bfloat16)
    wi = jnp.zeros((1, 8, _D), jnp.bfloat16)
    deg = jnp.ones((8,), jnp.int32)
    sc = jnp.ones((8,), jnp.float32)
    out = np.asarray(ctr_feature_fused(x, wr, wi, deg, sc, interpret=True))
    np.testing.assert_allclose(out[:, :8], _TRUE, rtol=1e-3)   # Re half
    np.testing.assert_allclose(out[:, 8:], 0.0, atol=1e-6)     # Im half


def test_tensor_sketch_fused_kernel_accumulates_fp32():
    from repro.kernels.tensor_sketch.ops import tensor_sketch_fused

    fs = 8
    x = jnp.full((4, _D), _VAL, jnp.bfloat16)
    wr = jnp.ones((1, fs, _D), jnp.bfloat16)
    wi = jnp.zeros((1, fs, _D), jnp.bfloat16)
    deg = jnp.ones((fs,), jnp.int32)
    mr = jnp.eye(fs, dtype=jnp.bfloat16)          # identity inverse-DFT
    mi = jnp.zeros((fs, fs), jnp.bfloat16)
    sc = jnp.ones((fs,), jnp.float32)
    out = np.asarray(tensor_sketch_fused(x, wr, wi, deg, mr, mi, sc,
                                         interpret=True))
    np.testing.assert_allclose(out, _TRUE, rtol=1e-3)


@pytest.mark.parametrize("name", ESTIMATORS)
def test_registry_bf16_path_not_bf16_accumulated(name):
    """Registry-level guard: if any family's bf16 path accumulated in
    bf16, a 512-term structured reduction would lose ~1% of its mass;
    the fp32-accum contract keeps it at fp32 rounding levels."""
    fm, _ = _build(name, d=512, F=64)
    x = jnp.full((3, 512), 2.0 ** -9)
    z32 = np.asarray(fm.apply(x, use_pallas=False))
    zb = np.asarray(fm.apply(x, use_pallas=True, interpret=True,
                             precision="bf16"))
    scale = max(float(np.max(np.abs(z32))), 1e-6)
    assert float(np.max(np.abs(zb - z32))) <= 2e-3 * scale