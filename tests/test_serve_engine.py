"""Serving engine correctness: continuous batching must be invisible —
greedy generations match a straight full-forward argmax rollout, regardless
of slot count or admission order."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import forward, init_model
from repro.serve import Request, ServingEngine


def _rollout_reference(cfg, params, prompt, n_new):
    """Greedy decode via repeated FULL forward passes (no cache)."""
    tokens = list(int(t) for t in prompt)
    out = []
    for _ in range(n_new):
        batch = {"tokens": jnp.asarray([tokens], jnp.int32)}
        logits, _ = forward(params, cfg, batch)
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        tokens.append(nxt)
    return out


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-1.7b", smoke=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params = init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_matches_full_forward_rollout(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n) for n in (5, 9, 7)]
    want = [_rollout_reference(cfg, params, p, 6) for p in prompts]

    engine = ServingEngine(cfg, params, num_slots=2, max_len=64)
    for i, p in enumerate(prompts):
        engine.submit(Request(request_id=i, prompt=p, max_new_tokens=6))
    done = engine.run()
    for i in range(len(prompts)):
        assert done[i].generated == want[i], (
            f"req {i}: engine={done[i].generated} reference={want[i]}"
        )


def test_engine_slot_count_invariance(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=6) for _ in range(4)]

    results = {}
    for slots in (1, 4):
        engine = ServingEngine(cfg, params, num_slots=slots, max_len=64)
        for i, p in enumerate(prompts):
            engine.submit(Request(request_id=i, prompt=p, max_new_tokens=5))
        done = engine.run()
        results[slots] = {i: done[i].generated for i in range(len(prompts))}
    assert results[1] == results[4]


def test_engine_rm_mode_runs(setup):
    cfg0, _ = setup
    cfg = get_config("qwen3-1.7b", smoke=True, attention_mode="rm")
    params = init_model(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, num_slots=2, max_len=64)
    rng = np.random.default_rng(2)
    for i in range(3):
        engine.submit(Request(request_id=i,
                              prompt=rng.integers(0, cfg.vocab_size, size=5),
                              max_new_tokens=4))
    done = engine.run()
    assert len(done) == 3
    assert all(len(s.generated) == 4 for s in done.values())


def test_engine_rejects_encoder(setup):
    cfg = get_config("hubert-xlarge", smoke=True)
    with pytest.raises(ValueError, match="encoder-only"):
        ServingEngine(cfg, {}, num_slots=1, max_len=16)


def test_launch_serve_forwards_estimator():
    """Regression: the serving launcher must thread ``estimator=`` into
    ``get_config`` — the engine validates the name at construction, so a
    dropped kwarg silently serves the default "rm" family instead of the
    requested one."""
    from repro.launch.serve import make_engine

    eng = make_engine("qwen3-1.7b", smoke=True, attention_mode="rm",
                      estimator="tensor_sketch", num_slots=1, max_len=32)
    assert eng.estimator == "tensor_sketch"
    assert eng.cfg.rm.estimator == "tensor_sketch"

    with pytest.raises(KeyError, match="no_such_estimator"):
        make_engine("qwen3-1.7b", smoke=True, attention_mode="rm",
                    estimator="no_such_estimator", num_slots=1, max_len=32)


def test_bucketed_prefill_rm_state_matches_unpadded():
    """Right-padding a prompt to a bucket with sentinel positions must leave
    the O(1) RM decode state (and the real-position logits) bit-unchanged —
    padded keys are masked out of the prefix sums (DESIGN.md §2)."""
    from repro.models.transformer import prefill

    cfg = get_config("qwen3-1.7b", smoke=True, attention_mode="rm")
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params = init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    t, tb = 5, 16
    prompt = rng.integers(0, cfg.vocab_size, size=t)

    tokens = jnp.asarray(prompt[None, :], jnp.int32)
    logits, cache = prefill(params, cfg, {"tokens": tokens}, 64)

    padded = np.zeros((1, tb), np.int32)
    padded[0, :t] = prompt
    positions = np.full((1, tb), -1, np.int32)
    positions[0, :t] = np.arange(t)
    logits_p, cache_p = prefill(
        params, cfg,
        {"tokens": jnp.asarray(padded), "positions": jnp.asarray(positions)},
        64,
    )

    np.testing.assert_allclose(np.asarray(logits_p[:, :t]),
                               np.asarray(logits), rtol=1e-5, atol=1e-5)
    flat = jax.tree_util.tree_leaves_with_path(cache)
    flat_p = dict(jax.tree_util.tree_leaves_with_path(cache_p))
    for path, leaf in flat:
        np.testing.assert_allclose(np.asarray(flat_p[path]),
                                   np.asarray(leaf), rtol=1e-5, atol=1e-6,
                                   err_msg=str(path))
