"""Serving engine correctness: continuous batching must be invisible —
greedy generations match a straight full-forward argmax rollout, regardless
of slot count or admission order."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import forward, init_model
from repro.serve import Request, ServingEngine


def _rollout_reference(cfg, params, prompt, n_new):
    """Greedy decode via repeated FULL forward passes (no cache)."""
    tokens = list(int(t) for t in prompt)
    out = []
    for _ in range(n_new):
        batch = {"tokens": jnp.asarray([tokens], jnp.int32)}
        logits, _ = forward(params, cfg, batch)
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        tokens.append(nxt)
    return out


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-1.7b", smoke=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params = init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_matches_full_forward_rollout(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n) for n in (5, 9, 7)]
    want = [_rollout_reference(cfg, params, p, 6) for p in prompts]

    engine = ServingEngine(cfg, params, num_slots=2, max_len=64)
    for i, p in enumerate(prompts):
        engine.submit(Request(request_id=i, prompt=p, max_new_tokens=6))
    done = engine.run()
    for i in range(len(prompts)):
        assert done[i].generated == want[i], (
            f"req {i}: engine={done[i].generated} reference={want[i]}"
        )


def test_engine_slot_count_invariance(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=6) for _ in range(4)]

    results = {}
    for slots in (1, 4):
        engine = ServingEngine(cfg, params, num_slots=slots, max_len=64)
        for i, p in enumerate(prompts):
            engine.submit(Request(request_id=i, prompt=p, max_new_tokens=5))
        done = engine.run()
        results[slots] = {i: done[i].generated for i in range(len(prompts))}
    assert results[1] == results[4]


def test_engine_rm_mode_runs(setup):
    cfg0, _ = setup
    cfg = get_config("qwen3-1.7b", smoke=True, attention_mode="rm")
    params = init_model(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, num_slots=2, max_len=64)
    rng = np.random.default_rng(2)
    for i in range(3):
        engine.submit(Request(request_id=i,
                              prompt=rng.integers(0, cfg.vocab_size, size=5),
                              max_new_tokens=4))
    done = engine.run()
    assert len(done) == 3
    assert all(len(s.generated) == 4 for s in done.values())


def _submit_n(engine, cfg, n, *, size=5, seed=7, **req_kw):
    rng = np.random.default_rng(seed)
    for i in range(n):
        engine.submit(Request(request_id=i,
                              prompt=rng.integers(0, cfg.vocab_size,
                                                  size=size),
                              **req_kw))


def _sampled_rollout_reference(cfg, params, prompt, n_new, temperature,
                               rng_seed=0):
    """Temperature decode via repeated FULL forward passes, replaying the
    engine's key discipline (one split per admit, one per decode step)."""
    from repro.serve.sampler import sample_token

    key = jax.random.PRNGKey(rng_seed)
    tokens = list(int(t) for t in prompt)
    out = []
    for step in range(n_new):
        batch = {"tokens": jnp.asarray([tokens], jnp.int32)}
        logits, _ = forward(params, cfg, batch)
        key, sub = jax.random.split(key)
        if step == 0:   # prefill samples at the raw request temperature
            tok = int(sample_token(logits[:, -1], sub, temperature)[0])
        else:           # decode: pre-scaled logits, shared T=1 categorical
            tok = int(sample_token(logits[:, -1] / temperature, sub, 1.0)[0])
        out.append(tok)
        tokens.append(tok)
    return out


@pytest.mark.parametrize("temperature", [0.25, 4.0])
def test_decode_respects_per_request_temperature(setup, temperature):
    """Regression: _decode_iteration used to sample every lane at a
    hardcoded temperature=1.0, so any request with 0 < T != 1 got the
    right distribution for its first (prefill-sampled) token and the
    wrong one for every subsequent token. The engine stream must equal
    the temperature-scaled reference rollout under the shared seed —
    under the old bug the decode tokens come from the T=1.0 categorical
    and diverge from this reference."""
    cfg, params = setup
    rng = np.random.default_rng(17)
    prompt = rng.integers(0, cfg.vocab_size, size=6)
    want = _sampled_rollout_reference(cfg, params, prompt, 8, temperature,
                                      rng_seed=0)

    engine = ServingEngine(cfg, params, num_slots=1, max_len=64, rng_seed=0)
    engine.submit(Request(request_id=0, prompt=prompt, max_new_tokens=8,
                          temperature=temperature))
    got = engine.run()[0].generated
    assert got == want, (temperature, got, want)


def test_hot_and_cold_streams_diverge(setup):
    """Same seed, different temperatures: the cold (0.25) and hot (4.0)
    streams must differ — under the old shared-T=1.0 decode both followed
    one categorical sequence."""
    cfg, params = setup

    def gen(temperature):
        engine = ServingEngine(cfg, params, num_slots=1, max_len=64,
                               rng_seed=123)
        _submit_n(engine, cfg, 1, temperature=temperature,
                  max_new_tokens=12)
        return engine.run()[0].generated

    cold, hot = gen(0.25), gen(4.0)
    assert len(cold) == len(hot) == 12
    assert cold != hot


def test_max_new_tokens_one_yields_exactly_one_token(setup):
    """Regression: _admit appended the prefill-sampled token without
    checking max_new_tokens, so max_new_tokens=1 returned 2 tokens and
    burned a decode iteration."""
    from repro.obs import Obs, clock

    cfg, params = setup
    obs = Obs(clock=clock.FakeClock(),
              provenance={"backend": "test", "device_kind": "test",
                          "device_count": 1, "interpret": False,
                          "jax_version": "0"})
    engine = ServingEngine(cfg, params, num_slots=2, max_len=64, obs=obs)
    _submit_n(engine, cfg, 3, max_new_tokens=1)
    done = engine.run()
    assert all(len(done[i].generated) == 1 for i in range(3))
    # the decode lane is never occupied: no decode/step span at all
    names = [r["name"] for r in obs.tracer.records if r["type"] != "meta"]
    assert "decode/step" not in names
    finishes = obs.tracer.events("request/finish")
    assert [e["attrs"]["reason"] for e in finishes] == ["max_new_tokens"] * 3
    obs.close()


def test_eos_first_token_finishes_without_decode(setup):
    """Regression: an EOS prefill-sampled token used to occupy a lane and
    burn a decode iteration anyway. Probe the deterministic greedy first
    token, then resubmit with eos_token pinned to it."""
    from repro.obs import Obs, clock

    cfg, params = setup
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, size=5)

    probe = ServingEngine(cfg, params, num_slots=1, max_len=64)
    probe.submit(Request(request_id=0, prompt=prompt, max_new_tokens=1))
    first = probe.run()[0].generated[0]

    obs = Obs(clock=clock.FakeClock(),
              provenance={"backend": "test", "device_kind": "test",
                          "device_count": 1, "interpret": False,
                          "jax_version": "0"})
    engine = ServingEngine(cfg, params, num_slots=1, max_len=64, obs=obs)
    engine.submit(Request(request_id=0, prompt=prompt, max_new_tokens=8,
                          eos_token=int(first)))
    done = engine.run()
    assert done[0].generated == [first]
    names = [r["name"] for r in obs.tracer.records if r["type"] != "meta"]
    assert "decode/step" not in names
    finishes = obs.tracer.events("request/finish")
    assert [e["attrs"]["reason"] for e in finishes] == ["eos"]
    obs.close()


def test_cache_exhaustion_reports_cache_full(setup):
    """A request whose budget outlives the decode cache stops at the cache
    boundary and says so — cache_full used to be indistinguishable from
    "length" (and mislabeled "eos" on a coinciding last token)."""
    from repro.obs import Obs, clock

    cfg, params = setup
    obs = Obs(clock=clock.FakeClock(),
              provenance={"backend": "test", "device_kind": "test",
                          "device_count": 1, "interpret": False,
                          "jax_version": "0"})
    engine = ServingEngine(cfg, params, num_slots=1, max_len=16, obs=obs)
    _submit_n(engine, cfg, 1, size=10, max_new_tokens=32)
    done = engine.run()
    # positions 10..14 decode (15 is the scratch slot): 1 prefill token +
    # 5 decode tokens
    assert len(done[0].generated) == 6
    finishes = obs.tracer.events("request/finish")
    assert [e["attrs"]["reason"] for e in finishes] == ["cache_full"]
    obs.close()


def test_run_warns_and_counts_on_max_iters_truncation(setup):
    """Regression: run() used to return normally when max_iters expired
    with work still pending — indistinguishable from a drained run."""
    from repro.obs import Obs, clock

    cfg, params = setup
    obs = Obs(clock=clock.FakeClock(),
              provenance={"backend": "test", "device_kind": "test",
                          "device_count": 1, "interpret": False,
                          "jax_version": "0"})
    engine = ServingEngine(cfg, params, num_slots=1, max_len=64, obs=obs)
    _submit_n(engine, cfg, 3, max_new_tokens=8)
    with pytest.warns(RuntimeWarning, match="max_iters=2.*truncated"):
        done = engine.run(max_iters=2)
    # slot 0's request is mid-decode and two more are queued
    assert len(done) == 0
    assert obs.metrics.snapshot()["counters"]["serve/truncated"] == 3.0
    # a subsequent unbounded run drains cleanly with no further warning
    import warnings as _warnings
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        done = engine.run()
    assert len(done) == 3
    obs.close()


def test_engine_rejects_encoder(setup):
    cfg = get_config("hubert-xlarge", smoke=True)
    with pytest.raises(ValueError, match="encoder-only"):
        ServingEngine(cfg, {}, num_slots=1, max_len=16)


def test_launch_serve_forwards_estimator():
    """Regression: the serving launcher must thread ``estimator=`` into
    ``get_config`` — the engine validates the name at construction, so a
    dropped kwarg silently serves the default "rm" family instead of the
    requested one."""
    from repro.launch.serve import make_engine

    eng = make_engine("qwen3-1.7b", smoke=True, attention_mode="rm",
                      estimator="tensor_sketch", num_slots=1, max_len=32)
    assert eng.estimator == "tensor_sketch"
    assert eng.cfg.rm.estimator == "tensor_sketch"

    with pytest.raises(KeyError, match="no_such_estimator"):
        make_engine("qwen3-1.7b", smoke=True, attention_mode="rm",
                    estimator="no_such_estimator", num_slots=1, max_len=32)


def test_bucketed_prefill_rm_state_matches_unpadded():
    """Right-padding a prompt to a bucket with sentinel positions must leave
    the O(1) RM decode state (and the real-position logits) bit-unchanged —
    padded keys are masked out of the prefix sums (DESIGN.md §2)."""
    from repro.models.transformer import prefill

    cfg = get_config("qwen3-1.7b", smoke=True, attention_mode="rm")
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params = init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    t, tb = 5, 16
    prompt = rng.integers(0, cfg.vocab_size, size=t)

    tokens = jnp.asarray(prompt[None, :], jnp.int32)
    logits, cache = prefill(params, cfg, {"tokens": tokens}, 64)

    padded = np.zeros((1, tb), np.int32)
    padded[0, :t] = prompt
    positions = np.full((1, tb), -1, np.int32)
    positions[0, :t] = np.arange(t)
    logits_p, cache_p = prefill(
        params, cfg,
        {"tokens": jnp.asarray(padded), "positions": jnp.asarray(positions)},
        64,
    )

    np.testing.assert_allclose(np.asarray(logits_p[:, :t]),
                               np.asarray(logits), rtol=1e-5, atol=1e-5)
    flat = jax.tree_util.tree_leaves_with_path(cache)
    flat_p = dict(jax.tree_util.tree_leaves_with_path(cache_p))
    for path, leaf in flat:
        np.testing.assert_allclose(np.asarray(flat_p[path]),
                                   np.asarray(leaf), rtol=1e-5, atol=1e-6,
                                   err_msg=str(path))


def test_engine_accepts_custom_bucket_ladder(setup):
    """Satellite regression (ISSUE 9): ``buckets=`` threads through
    ``ServingEngine.__init__`` to the executor, replacing the old
    hardcoded module tuple, and the effective ladder is clipped to
    ``max_len`` so no compiled prefill shape is unreachable."""
    cfg, params = setup
    engine = ServingEngine(cfg, params, num_slots=1, max_len=24,
                           buckets=(8, 16, 64))
    # 64 >= max_len is clipped; max_len itself caps the ladder
    assert engine.executor.buckets == (8, 16, 24)
    assert engine.executor.bucket_for(5) == 8
    assert engine.executor.bucket_for(9) == 16
    assert engine.executor.bucket_for(17) == 24
    with pytest.raises(ValueError, match="exceeds the largest"):
        engine.executor.bucket_for(25)
    # custom ladder serves identically to the default one
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, size=6)
    engine.submit(Request(request_id=0, prompt=prompt, max_new_tokens=4))
    default_engine = ServingEngine(cfg, params, num_slots=1, max_len=24)
    default_engine.submit(Request(request_id=0, prompt=prompt,
                                  max_new_tokens=4))
    assert engine.run()[0].generated == default_engine.run()[0].generated


def test_bucket_ladder_validation(setup):
    """Unsorted, non-positive or empty ladders fail at construction with
    the offending ladder named — not deep inside the first prefill."""
    cfg, params = setup
    for bad in [(), (0, 32), (-4, 8), (32, 16), (16, 16)]:
        with pytest.raises(ValueError, match="buckets"):
            ServingEngine(cfg, params, num_slots=1, max_len=64, buckets=bad)


def test_default_ladder_clipped_to_max_len(setup):
    """The old hardcoded ladder compiled prefill fns for buckets beyond
    max_len; now the effective ladder ends exactly at max_len."""
    from repro.serve import DEFAULT_BUCKETS, effective_buckets

    cfg, params = setup
    engine = ServingEngine(cfg, params, num_slots=1, max_len=64)
    assert engine.executor.buckets == (32, 64)
    assert engine.executor.buckets == effective_buckets(DEFAULT_BUCKETS, 64)
    assert max(engine.executor.buckets) == 64
