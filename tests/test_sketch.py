"""TensorSketch subsystem: kernel parity, registry protocol, integration.

Covers (DESIGN.md §9):
  * fused Pallas kernel (interpret mode) vs the jnp.fft oracle to 1e-5 on
    the kernel zoo, plus ONE-launch accounting;
  * CountSketch scatter correctness against the dense one-hot matmul;
  * estimator-registry protocol: both entries expose make_plan/init_params/
    apply/output_dim/truncation_bias and drop into make_feature_map,
    attention, and the serving engine with no special-casing;
  * chunked Gram estimation parity (satellite);
  * FeaturePlan/SketchPlan (seed, allocation) serialization round-trips
    (satellite).

Reproducibility: every statistical test in this module draws from PINNED
PRNG seeds (explicit jax.random.PRNGKey / np.random.default_rng constants —
no time- or run-dependent entropy), so tier-1 results are identical across
runs and machines; hypothesis-driven modules get the same guarantee from
the derandomized "ci" profile in conftest.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ExponentialDotProductKernel,
    HomogeneousPolynomialKernel,
    PolynomialKernel,
    VovkRealKernel,
    make_feature_map,
    registry,
)
from repro.core.plan import make_feature_plan, FeaturePlan
from repro.kernels.tensor_sketch import tensor_sketch_fused
from repro.sketch import (
    SketchFeatureMap,
    SketchPlan,
    count_sketch_ref,
    make_sketch_feature_map,
    make_sketch_plan,
    pack_sketch,
    tensor_sketch_fused_ref,
)

KERNELS = [
    ExponentialDotProductKernel(1.0),
    PolynomialKernel(7, 1.0),
    HomogeneousPolynomialKernel(3),
    VovkRealKernel(4),
]


# ---------------------------------------------------------------------------
# kernel parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("h01", [False, True])
def test_zoo_parity_fused_vs_fft_oracle(kern, h01):
    if h01 and kern.coef(0) == 0.0 and kern.coef(1) == 0.0:
        pytest.skip("H0/1 undefined for homogeneous kernels (paper §6.2)")
    fm = make_sketch_feature_map(kern, 24, 192, jax.random.PRNGKey(5),
                                 h01=h01)
    x = jax.random.normal(jax.random.PRNGKey(6), (11, 24)) * 0.25

    want = fm(x)                              # jnp.fft oracle
    got = fm.apply(x, use_pallas=True, interpret=True)

    assert want.shape == (11, fm.output_dim)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_tensor_sketch_fused_raw_parity():
    """Array-level fused op agrees with its jnp mirror on packed layouts."""
    kern = PolynomialKernel(5, 0.5)
    fm = make_sketch_feature_map(kern, 13, 97, jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (3, 5, 13)) * 0.2
    wr, wi, mr, mi = pack_sketch(fm.plan, fm.params)
    cd = jnp.asarray(fm.plan.column_degrees())
    cs = jnp.asarray(fm.plan.column_scales())
    want = tensor_sketch_fused_ref(x.reshape(-1, 13), wr, wi, cd, mr, mi, cs)
    got = tensor_sketch_fused(x, wr, wi, cd, mr, mi, cs,
                              use_pallas=True, interpret=True)
    assert got.shape == (3, 5, fm.plan.num_sketch_cols)
    np.testing.assert_allclose(np.asarray(got).reshape(-1, want.shape[-1]),
                               np.asarray(want), rtol=1e-5, atol=1e-5)


def test_sketch_fused_is_one_pallas_launch():
    """Every degree block — CountSketch, product, inverse-DFT — ONE launch."""
    kern = ExponentialDotProductKernel(1.0)
    fm = make_sketch_feature_map(kern, 16, 256, jax.random.PRNGKey(0))
    assert len(fm.plan.degrees) > 1
    x = jnp.ones((4, 16)) * 0.1

    def count_in(jaxpr):
        total = 0
        for eqn in jaxpr.eqns:
            if "pallas" in eqn.primitive.name:
                total += 1
            for v in eqn.params.values():
                if hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
                    total += count_in(v.jaxpr)
                elif hasattr(v, "eqns"):
                    total += count_in(v)
        return total

    fn = lambda xx: fm.apply(xx, use_pallas=True, interpret=True)
    assert count_in(jax.make_jaxpr(fn)(x).jaxpr) == 1


def test_count_sketch_ref_scatter():
    """Scatter-by-hash equals the dense signed one-hot matmul."""
    rng = np.random.default_rng(0)
    d, width, b = 17, 8, 5
    h = jnp.asarray(rng.integers(0, width, d), jnp.int32)
    s = jnp.asarray(rng.choice([-1.0, 1.0], d), jnp.float32)
    x = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    got = count_sketch_ref(x, h, s, width)
    dense = np.zeros((d, width), np.float32)
    dense[np.arange(d), np.asarray(h)] = np.asarray(s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x) @ dense,
                               rtol=1e-6, atol=1e-6)


def test_tiny_budget_and_width_one_blocks():
    """Width-1 sketches (FFT of length 1) degenerate gracefully."""
    kern = PolynomialKernel(3, 1.0)
    fm = make_sketch_feature_map(kern, 6, 5, jax.random.PRNGKey(1))
    assert fm.output_dim <= 5
    x = jax.random.normal(jax.random.PRNGKey(2), (7, 6)) * 0.3
    want = fm(x)
    got = fm.apply(x, use_pallas=True, interpret=True)
    assert np.isfinite(np.asarray(want)).all()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_sketch_gram_estimates_kernel():
    """Averaged over maps, the TS Gram approaches the exact Gram, and the
    residual shrinks as the budget grows."""
    kern = ExponentialDotProductKernel(1.0)
    d = 12
    X = jax.random.normal(jax.random.PRNGKey(0), (10, d))
    X = X / jnp.linalg.norm(X, axis=1, keepdims=True) * 0.8
    K = np.asarray(kern.gram(X))

    def err(F, n_maps=8):
        grams = []
        for s in range(n_maps):
            fm = make_sketch_feature_map(kern, d, F, jax.random.PRNGKey(s),
                                         measure="proportional")
            grams.append(np.asarray(fm.estimate_gram(X)))
        return np.abs(np.mean(grams, axis=0) - K).max()

    e_small, e_big = err(64), err(1024)
    assert e_big < e_small
    assert e_big < 0.15 * np.abs(K).max()


def test_estimator_variance_comparison():
    """At a matched budget the TensorSketch Gram-entry estimator has LOWER
    variance than Random Maclaurin for the exponential kernel (the regime
    Wacker et al. identify: inhomogeneous kernel, moderate F) — and both are
    unbiased to Monte-Carlo precision. Fixed seeds: deterministic.
    """
    kern = ExponentialDotProductKernel(1.0)
    d, F, n_draws = 8, 256, 120
    kx, ky = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.normal(kx, (d,))
    x = x / jnp.linalg.norm(x) * 0.9
    y = jax.random.normal(ky, (d,))
    y = y / jnp.linalg.norm(y) * 0.9
    exact = float(kern.f(float(x @ y)))

    stats = {}
    for estimator in ("rm", "tensor_sketch"):
        vals = []
        for s in range(n_draws):
            fm = make_feature_map(kern, d, F, jax.random.PRNGKey(1000 + s),
                                  measure="proportional",
                                  estimator=estimator)
            vals.append(float((fm(x[None]) @ fm(y[None]).T)[0, 0]))
        vals = np.asarray(vals)
        stats[estimator] = (vals.mean(), vals.var())
        # unbiased within 4 standard errors of the empirical mean
        se = np.sqrt(vals.var() / n_draws)
        assert abs(vals.mean() - exact) < 4.0 * se + 1e-3, (estimator, stats)

    assert stats["tensor_sketch"][1] < stats["rm"][1], stats


# ---------------------------------------------------------------------------
# registry protocol
# ---------------------------------------------------------------------------
def test_registry_entries_share_protocol():
    kern = ExponentialDotProductKernel(1.0)
    for name in ("rm", "tensor_sketch"):
        est = registry.get(name)
        assert est.name == name
        plan = est.make_plan(kern, 8, 96, measure="proportional",
                            stratified=True, seed=3)
        params = est.init_params(plan, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (5, 8)) * 0.2
        z = est.apply(plan, params, x, use_pallas=False)
        assert z.shape == (5, est.output_dim(plan))
        assert est.output_dim(plan) == plan.output_dim
        assert est.truncation_bias(plan, 1.0) >= 0.0
        assert plan.seed == 3


def test_registry_unknown_name():
    with pytest.raises(KeyError, match="tensor_sketch"):
        registry.get("does_not_exist")


def test_make_feature_map_estimator_kwarg():
    kern = PolynomialKernel(3, 1.0)
    fm = make_feature_map(kern, 10, 64, jax.random.PRNGKey(0),
                          estimator="tensor_sketch")
    assert isinstance(fm, SketchFeatureMap)
    from repro.core import train_featurized_linear

    # quadratic (XOR-like) boundary: linearly inseparable in input space
    X = jax.random.normal(jax.random.PRNGKey(1), (80, 10)) * 0.4
    y = jnp.sign(X[:, 0] * X[:, 1] + 1e-3)
    clf = train_featurized_linear(fm, X, y, n_iters=10)
    assert clf.accuracy(X, y) > 0.7


# ---------------------------------------------------------------------------
# model / engine integration (no consumer-side special-casing)
# ---------------------------------------------------------------------------
def test_attention_and_engine_with_tensor_sketch():
    from repro.configs import get_config
    from repro.models.transformer import init_model, forward
    from repro.serve.engine import Request, ServingEngine

    cfg = get_config("qwen3-1.7b", smoke=True, attention_mode="rm",
                     estimator="tensor_sketch")
    assert cfg.rm.estimator == "tensor_sketch"
    params = init_model(cfg, jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.ones((2, 16), jnp.int32),
        "positions": jnp.tile(jnp.arange(16), (2, 1)),
    }
    logits, _ = forward(params, cfg, batch)
    assert logits.shape[:2] == (2, 16)
    assert np.isfinite(np.asarray(logits)).all()

    eng = ServingEngine(cfg, params, num_slots=2, max_len=64)
    assert eng.estimator == "tensor_sketch"
    eng.submit(Request(0, np.arange(5, dtype=np.int32) % 7,
                       max_new_tokens=4))
    done = eng.run(max_iters=50)
    assert len(done[0].generated) == 4


def test_engine_rejects_unknown_estimator():
    import dataclasses

    from repro.configs import get_config
    from repro.serve.engine import ServingEngine

    cfg = get_config("qwen3-1.7b", smoke=True, attention_mode="rm")
    bad = dataclasses.replace(
        cfg, rm=dataclasses.replace(cfg.rm, estimator="nope")
    )
    with pytest.raises(KeyError, match="nope"):
        ServingEngine(bad, params=None, num_slots=1, max_len=32)


# ---------------------------------------------------------------------------
# satellites: chunked gram + plan serialization
# ---------------------------------------------------------------------------
def test_estimate_gram_chunked_matches_unchunked():
    kern = ExponentialDotProductKernel(1.0)
    X = jax.random.normal(jax.random.PRNGKey(0), (23, 9)) * 0.3
    Y = jax.random.normal(jax.random.PRNGKey(1), (11, 9)) * 0.3
    for estimator in ("rm", "tensor_sketch"):
        fm = make_feature_map(kern, 9, 64, jax.random.PRNGKey(2),
                              estimator=estimator)
        full = fm.estimate_gram(X, Y)
        chunked = fm.estimate_gram(X, Y, row_chunk=5)
        assert full.shape == (23, 11)
        np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                                   rtol=1e-5, atol=1e-6)


def test_feature_plan_records_seed_and_roundtrips():
    kern = ExponentialDotProductKernel(1.0)
    plan = make_feature_plan(kern, 8, 128, stratified=False, seed=1234)
    assert plan.seed == 1234
    assert "1234" in repr(plan)
    again = make_feature_plan(kern, 8, 128, stratified=False, seed=1234)
    assert again == plan                       # same seed -> same allocation
    other = make_feature_plan(kern, 8, 128, stratified=False, seed=77)
    assert other.seed == 77

    rt = FeaturePlan.from_json(plan.to_json())
    assert rt == plan
    assert isinstance(rt.degrees, tuple)


def test_sketch_plan_roundtrips():
    kern = PolynomialKernel(5, 1.0)
    plan = make_sketch_plan(kern, 8, 96, seed=9)
    rt = SketchPlan.from_json(plan.to_json())
    assert rt == plan
    assert rt.seed == 9
    # hashable / jit-static
    assert hash(rt) == hash(plan)
