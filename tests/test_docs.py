"""Documentation contract for the public estimator surface.

Two guarantees (ISSUE 4 satellite):

  * every entry of ``registry.list_estimators()`` exposes protocol
    functions (``make_plan`` / ``init_params`` / ``apply`` / ``make_map`` /
    ``output_dim`` / ``truncation_bias``) with non-empty docstrings — a new
    family cannot register half-documented;
  * every symbol exported (``__all__``) by the public registry-surface
    modules — ``core.registry``, ``core.feature_map``, ``core.plan``,
    ``sketch.plan``, ``ctr.plan``, ``distributed.estimator`` — carries a
    docstring, and so does every public method of the plan/map classes.
"""
import inspect

import pytest

from repro.core import registry

PROTOCOL_FIELDS = ("make_plan", "init_params", "apply", "make_map",
                   "output_dim", "truncation_bias")


@pytest.mark.parametrize("name", registry.list_estimators())
def test_protocol_methods_have_docstrings(name):
    est = registry.get(name)
    for field in PROTOCOL_FIELDS:
        fn = getattr(est, field)
        doc = inspect.getdoc(fn)
        assert doc and doc.strip(), (
            f"estimator {name!r}: protocol function {field!r} has no "
            "docstring — document it where the entry is built"
        )


MODULES = [
    "repro.core.registry",
    "repro.core.feature_map",
    "repro.core.plan",
    "repro.sketch.plan",
    "repro.ctr.plan",
    "repro.distributed.estimator",
]


@pytest.mark.parametrize("modname", MODULES)
def test_exported_symbols_have_docstrings(modname):
    import importlib

    mod = importlib.import_module(modname)
    assert (mod.__doc__ or "").strip(), f"{modname} has no module docstring"
    exported = getattr(mod, "__all__", None)
    assert exported, f"{modname} defines no __all__"
    for sym in exported:
        obj = getattr(mod, sym)
        if not callable(obj) and not inspect.isclass(obj):
            continue                      # constants (e.g. BIAS_TAIL_DEGREES)
        doc = inspect.getdoc(obj)
        assert doc and doc.strip(), f"{modname}.{sym} has no docstring"


def test_plan_and_map_public_methods_have_docstrings():
    from repro.core.feature_map import RMFeatureMap
    from repro.core.plan import FeaturePlan
    from repro.ctr.feature_map import CtrFeatureMap
    from repro.ctr.plan import CtrPlan
    from repro.distributed.estimator import ShardedFeatureMap
    from repro.sketch.feature_map import SketchFeatureMap
    from repro.sketch.plan import SketchPlan

    for cls in (FeaturePlan, SketchPlan, CtrPlan, RMFeatureMap,
                SketchFeatureMap, CtrFeatureMap, ShardedFeatureMap):
        for name, member in vars(cls).items():
            if name.startswith("_") or name in ("tree_flatten",
                                                "tree_unflatten"):
                continue
            # properties that merely forward a plan field may go
            # undocumented; every plain method must say what it computes.
            if isinstance(member, property) or not callable(member):
                continue
            doc = inspect.getdoc(member)
            assert doc and doc.strip(), f"{cls.__name__}.{name}"
