"""Complex-to-real (CTR) estimator subsystem: kernel parity, variance,
registry protocol, integration.

Covers (DESIGN.md §11):
  * fused Pallas kernel (interpret mode) vs the complex64 oracle to 1e-5 on
    the kernel zoo, plus ONE-launch accounting;
  * the CtR identity ``<z_R(x), z_R(y)> = Re(<z(x), conj(z(y))>)`` against
    an explicit complex-product computation;
  * the ISSUE-4 acceptance claim: at a matched real feature budget the CTR
    Gram MSE on the exponential kernel is <= Random Maclaurin's
    (deterministic seeds);
  * registry threading: ``make_feature_map(estimator="ctr")``,
    ``train_featurized_linear``, attention forward, and the serving engine
    with no consumer-side special-casing.

Reproducibility: every statistical test draws from PINNED PRNG seeds, so
tier-1 results are identical across runs and machines.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ExponentialDotProductKernel,
    HomogeneousPolynomialKernel,
    PolynomialKernel,
    VovkRealKernel,
    make_feature_map,
    registry,
)
from repro.ctr import (
    CtrFeatureMap,
    CtrPlan,
    ctr_feature_fused_ref,
    init_ctr_params,
    make_ctr_feature_map,
    make_ctr_plan,
    pack_ctr,
)
from repro.kernels.ctr_feature import ctr_feature_fused

KERNELS = [
    ExponentialDotProductKernel(1.0),
    PolynomialKernel(7, 1.0),
    HomogeneousPolynomialKernel(3),
    VovkRealKernel(4),
]


# ---------------------------------------------------------------------------
# kernel parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("h01", [False, True])
def test_zoo_parity_fused_vs_complex_oracle(kern, h01):
    if h01 and kern.coef(0) == 0.0 and kern.coef(1) == 0.0:
        pytest.skip("H0/1 undefined for homogeneous kernels (paper §6.2)")
    fm = make_ctr_feature_map(kern, 24, 192, jax.random.PRNGKey(5), h01=h01)
    x = jax.random.normal(jax.random.PRNGKey(6), (11, 24)) * 0.25

    want = fm(x)                              # complex64 oracle
    got = fm.apply(x, use_pallas=True, interpret=True)

    assert want.shape == (11, fm.output_dim)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_ctr_fused_raw_parity():
    """Array-level fused op agrees with its jnp mirror on packed layouts."""
    kern = PolynomialKernel(5, 0.5)
    fm = make_ctr_feature_map(kern, 13, 97, jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (3, 5, 13)) * 0.2
    wr, wi = pack_ctr(fm.plan, fm.params)
    cd = jnp.asarray(fm.plan.column_degrees())
    cs = jnp.asarray(fm.plan.column_scales())
    want = ctr_feature_fused_ref(x.reshape(-1, 13), wr, wi, cd, cs)
    got = ctr_feature_fused(x, wr, wi, cd, cs,
                            use_pallas=True, interpret=True)
    assert got.shape == (3, 5, 2 * fm.plan.num_complex)
    np.testing.assert_allclose(np.asarray(got).reshape(-1, want.shape[-1]),
                               np.asarray(want), rtol=1e-5, atol=1e-5)


def test_ctr_fused_is_one_pallas_launch():
    """Every complex bucket — all degrees, both halves — ONE launch."""
    kern = ExponentialDotProductKernel(1.0)
    fm = make_ctr_feature_map(kern, 16, 256, jax.random.PRNGKey(0))
    assert len(fm.plan.degrees) > 1
    x = jnp.ones((4, 16)) * 0.1

    def count_in(jaxpr):
        total = 0
        for eqn in jaxpr.eqns:
            if "pallas" in eqn.primitive.name:
                total += 1
            for v in eqn.params.values():
                if hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
                    total += count_in(v.jaxpr)
                elif hasattr(v, "eqns"):
                    total += count_in(v)
        return total

    fn = lambda xx: fm.apply(xx, use_pallas=True, interpret=True)
    assert count_in(jax.make_jaxpr(fn)(x).jaxpr) == 1


def test_ctr_identity_against_explicit_complex_product():
    """The stacked [Re | Im] columns satisfy
    ``<z_R(x), z_R(y)> == Re(<z_C(x), conj(z_C(y))>)`` exactly — the CtR
    construction of Wacker et al."""
    kern = ExponentialDotProductKernel(1.0)
    plan = make_ctr_plan(kern, 9, 64, measure="proportional")
    params = init_ctr_params(plan, jax.random.PRNGKey(4))
    x = jax.random.normal(jax.random.PRNGKey(5), (6, 9)) * 0.3
    y = jax.random.normal(jax.random.PRNGKey(6), (6, 9)) * 0.3

    # explicit complex products, bucket by bucket
    w = params["wr"] + 1j * params["wi"]
    def zc(v):
        proj = v.astype(jnp.complex64) @ w.T
        outs, off = [], 0
        for n, c, s in zip(plan.degrees, plan.counts, plan.scales):
            blk = proj[:, off : off + c * n].reshape(-1, c, n)
            outs.append(jnp.prod(blk, axis=-1) * s)
            off += c * n
        return jnp.concatenate(outs, axis=-1)

    want = np.real(np.asarray(zc(x)) @ np.conj(np.asarray(zc(y))).T)
    from repro.ctr.plan import apply_ctr_plan

    zx = np.asarray(apply_ctr_plan(plan, params, x, use_pallas=False))
    zy = np.asarray(apply_ctr_plan(plan, params, y, use_pallas=False))
    pre = plan.num_prefix_columns
    got = zx[:, pre:] @ zy[:, pre:].T
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_tiny_budget_and_empty_buckets():
    """Tiny budgets (0 or 1 complex feature) degenerate gracefully."""
    kern = PolynomialKernel(3, 1.0)
    fm = make_ctr_feature_map(kern, 6, 5, jax.random.PRNGKey(1))
    assert fm.output_dim <= 5
    x = jax.random.normal(jax.random.PRNGKey(2), (7, 6)) * 0.3
    want = fm(x)
    got = fm.apply(x, use_pallas=True, interpret=True)
    assert np.isfinite(np.asarray(want)).all()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # const-only plan: no randomness at all
    tiny = make_ctr_feature_map(kern, 6, 1, jax.random.PRNGKey(1))
    z = tiny.apply(x, use_pallas=True, interpret=True)
    assert z.shape == (7, tiny.output_dim)
    # fully degenerate: a_0 = 0 (no prefix) AND the halved budget funds no
    # complex feature -> a valid 0-column map, not a concat error
    empty = make_ctr_feature_map(HomogeneousPolynomialKernel(3), 6, 1,
                                 jax.random.PRNGKey(1))
    assert empty.output_dim == 0
    assert empty(x).shape == (7, 0)
    assert empty.apply(x, use_pallas=True, interpret=True).shape == (7, 0)
    assert empty.estimate_gram(x).shape == (7, 7)


def test_ctr_gram_estimates_kernel():
    """Averaged over maps, the CTR Gram approaches the exact Gram, and the
    residual shrinks as the budget grows."""
    kern = ExponentialDotProductKernel(1.0)
    d = 12
    X = jax.random.normal(jax.random.PRNGKey(0), (10, d))
    X = X / jnp.linalg.norm(X, axis=1, keepdims=True) * 0.8
    K = np.asarray(kern.gram(X))

    def err(F, n_maps=8):
        grams = []
        for s in range(n_maps):
            fm = make_ctr_feature_map(kern, d, F, jax.random.PRNGKey(s),
                                      measure="proportional")
            grams.append(np.asarray(fm.estimate_gram(X)))
        return np.abs(np.mean(grams, axis=0) - K).max()

    e_small, e_big = err(64), err(1024)
    assert e_big < e_small
    assert e_big < 0.15 * np.abs(K).max()


def test_ctr_gram_mse_leq_rm_at_matched_budget():
    """ISSUE-4 acceptance: deterministic variance comparison — the CTR Gram
    MSE on the exponential kernel is <= Random Maclaurin's at the SAME real
    feature budget F (the Wacker et al. complex-feature variance reduction;
    per-degree win on aligned pairs, a tie at degree 1 — DESIGN.md §11).
    Fixed seeds.
    """
    kern = ExponentialDotProductKernel(1.0)
    d, F, n_draws = 8, 256, 60
    X = jax.random.normal(jax.random.PRNGKey(0), (12, d))
    X = X / jnp.linalg.norm(X, axis=1, keepdims=True) * 0.9
    K = np.asarray(kern.gram(X))

    mse = {}
    for name in ("rm", "ctr"):
        errs = []
        for s in range(n_draws):
            fm = make_feature_map(kern, d, F, jax.random.PRNGKey(1000 + s),
                                  estimator=name, measure="proportional")
            G = np.asarray(fm.estimate_gram(X))
            errs.append(np.mean((G - K) ** 2))
        mse[name] = float(np.mean(errs))

    assert mse["ctr"] <= mse["rm"], mse


# ---------------------------------------------------------------------------
# registry threading (no consumer-side special-casing)
# ---------------------------------------------------------------------------
def test_registry_lists_all_families():
    assert set(registry.list_estimators()) == {
        "rm", "tensor_sketch", "ctr", "structured"}


def test_make_feature_map_estimator_kwarg_ctr():
    kern = PolynomialKernel(3, 1.0)
    fm = make_feature_map(kern, 10, 64, jax.random.PRNGKey(0),
                          estimator="ctr")
    assert isinstance(fm, CtrFeatureMap)
    from repro.core import train_featurized_linear

    # quadratic (XOR-like) boundary: linearly inseparable in input space
    X = jax.random.normal(jax.random.PRNGKey(1), (80, 10)) * 0.4
    y = jnp.sign(X[:, 0] * X[:, 1] + 1e-3)
    clf = train_featurized_linear(fm, X, y, n_iters=10)
    assert clf.accuracy(X, y) > 0.7


def test_ctr_plan_roundtrips_and_iid_mode():
    kern = ExponentialDotProductKernel(1.0)
    plan = make_ctr_plan(kern, 8, 128, stratified=False, seed=1234)
    assert plan.seed == 1234
    again = make_ctr_plan(kern, 8, 128, stratified=False, seed=1234)
    assert again == plan                       # same seed -> same allocation
    rt = CtrPlan.from_json(plan.to_json())
    assert rt == plan
    assert hash(rt) == hash(plan)
    # iid mode stays applicable end-to-end
    params = init_ctr_params(plan, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8)) * 0.2
    est = registry.get("ctr")
    z = est.apply(plan, params, x, use_pallas=False)
    assert z.shape == (4, plan.output_dim)


def test_attention_and_engine_with_ctr():
    from repro.configs import get_config
    from repro.models.transformer import init_model, forward
    from repro.serve.engine import Request, ServingEngine

    cfg = get_config("qwen3-1.7b", smoke=True, attention_mode="rm",
                     estimator="ctr")
    assert cfg.rm.estimator == "ctr"
    params = init_model(cfg, jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.ones((2, 16), jnp.int32),
        "positions": jnp.tile(jnp.arange(16), (2, 1)),
    }
    logits, _ = forward(params, cfg, batch)
    assert logits.shape[:2] == (2, 16)
    assert np.isfinite(np.asarray(logits)).all()

    eng = ServingEngine(cfg, params, num_slots=2, max_len=64)
    assert eng.estimator == "ctr"
    eng.submit(Request(0, np.arange(5, dtype=np.int32) % 7,
                       max_new_tokens=4))
    done = eng.run(max_iters=50)
    assert len(done[0].generated) == 4
