"""Unit tests for the kernel zoo and Maclaurin coefficients."""
import math

import numpy as np
import pytest

from repro.core import (
    ExponentialDotProductKernel,
    HomogeneousPolynomialKernel,
    MaclaurinKernel,
    PolynomialKernel,
    VovkInfiniteKernel,
    VovkRealKernel,
    kernel_from_name,
)

KERNELS = [
    ExponentialDotProductKernel(1.0),
    ExponentialDotProductKernel(4.0),
    PolynomialKernel(10, 1.0),
    PolynomialKernel(3, 0.5),
    HomogeneousPolynomialKernel(10),
    HomogeneousPolynomialKernel(2),
    VovkRealKernel(5),
    VovkInfiniteKernel(),
]


@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: k.name)
def test_series_matches_closed_form(kern):
    xs = np.linspace(-0.8, 0.8, 17)
    if np.isfinite(kern.radius):
        xs = xs * min(0.9, kern.radius)
    np.testing.assert_allclose(
        kern.series_eval(xs, 96), np.asarray(kern.f(xs), dtype=np.float64),
        rtol=1e-8, atol=1e-8,
    )


@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: k.name)
def test_fprime_matches_finite_difference(kern):
    xs = np.linspace(-0.5, 0.5, 7)
    h = 1e-6
    fd = (np.asarray(kern.f(xs + h)) - np.asarray(kern.f(xs - h))) / (2 * h)
    np.testing.assert_allclose(np.asarray(kern.fprime(xs)), fd, rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: k.name)
def test_positive_definite_validation_passes(kern):
    kern.validate_positive_definite()


def test_negative_coefficient_detected():
    bad = MaclaurinKernel(coef_fn=lambda n: (-1.0) ** n, label="alternating")
    with pytest.raises(ValueError, match="negative Maclaurin"):
        bad.validate_positive_definite()


def test_exponential_coefficients_are_inverse_factorials():
    k = ExponentialDotProductKernel(1.0)
    for n in range(12):
        assert math.isclose(k.coef(n), 1.0 / math.factorial(n), rel_tol=1e-12)


def test_polynomial_coefficients_binomial():
    k = PolynomialKernel(4, 2.0)
    # (x+2)^4 = 16 + 32x + 24x^2 + 8x^3 + x^4
    np.testing.assert_allclose(k.coefs(5), [16, 32, 24, 8, 1, 0])


def test_kernel_from_name_roundtrip():
    assert kernel_from_name("exp", sigma2=2.0).sigma2 == 2.0
    assert kernel_from_name("poly", degree=3).degree == 3
    assert kernel_from_name("homogeneous", degree=2).degree == 2
    with pytest.raises(ValueError):
        kernel_from_name("nonexistent")


def test_gram_psd_on_unit_ball():
    """Schoenberg: the exact Gram matrix must be PSD for points in B_2(0,1)."""
    rng = np.random.default_rng(0)
    X = rng.normal(size=(40, 8))
    X /= np.linalg.norm(X, axis=1, keepdims=True) * 1.01
    for kern in KERNELS:
        G = np.asarray(kern.gram(X), dtype=np.float64)
        eigs = np.linalg.eigvalsh((G + G.T) / 2)
        assert eigs.min() > -1e-6 * max(1.0, eigs.max()), kern.name
