"""Pallas rm_attention kernel vs oracles, plus semantic checks:
chunked == quadratic == scanned; decode == incremental causal; RM linear
attention -> exact softmax attention as feature count grows."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ExponentialDotProductKernel, make_feature_map
from repro.kernels.rm_attention.ops import (
    rm_attention_causal,
    rm_attention_decode_step,
    rm_attention_noncausal,
    rm_attention_prefill_final_state,
)
from repro.kernels.rm_attention.ref import (
    rm_attention_ref,
    rm_attention_scan_ref,
)

SHAPES = [
    # (b, h, t, f, dv, chunk)
    (1, 1, 16, 8, 8, 8),
    (2, 3, 64, 32, 16, 16),
    (1, 2, 100, 24, 8, 32),   # t not divisible by chunk -> padding
    (2, 1, 128, 128, 64, 64),
    (1, 1, 37, 5, 3, 16),
]


def _rand_inputs(key, b, h, t, f, dv, dtype=jnp.float32, positive=False):
    k1, k2, k3 = jax.random.split(key, 3)
    zq = jax.random.normal(k1, (b, h, t, f), dtype)
    zk = jax.random.normal(k2, (b, h, t, f), dtype)
    if positive:
        zq, zk = jnp.abs(zq) + 0.1, jnp.abs(zk) + 0.1
    v = jax.random.normal(k3, (b, h, t, dv), dtype)
    return zq, zk, v


@pytest.mark.parametrize("b,h,t,f,dv,chunk", SHAPES)
def test_chunked_pallas_matches_quadratic_oracle(b, h, t, f, dv, chunk):
    # positive features sidestep denominator sign flips so the comparison is
    # numerically clean; the signed case is covered separately below.
    zq, zk, v = _rand_inputs(jax.random.PRNGKey(t), b, h, t, f, dv,
                             positive=True)
    got = rm_attention_causal(zq, zk, v, chunk=chunk, use_pallas=True,
                              interpret=True)
    want = rm_attention_ref(zq, zk, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_signed_features_clamp_consistency():
    zq, zk, v = _rand_inputs(jax.random.PRNGKey(0), 2, 2, 48, 16, 8)
    got = rm_attention_causal(zq, zk, v, chunk=16, eps=1e-3, interpret=True)
    want = rm_attention_ref(zq, zk, v, causal=True, eps=1e-3)
    # where |den| is comfortably above the clamp, results agree tightly
    w = jnp.einsum("bhtf,bhsf->bhts", zq, zk)
    mask = jnp.tril(jnp.ones((48, 48), dtype=bool))
    den = jnp.sum(jnp.where(mask, w, 0.0), -1)
    ok = np.asarray(jnp.abs(den) > 1e-2)
    np.testing.assert_allclose(np.asarray(got)[ok], np.asarray(want)[ok],
                               rtol=1e-3, atol=1e-3)


def test_scan_ref_equals_quadratic_ref():
    zq, zk, v = _rand_inputs(jax.random.PRNGKey(1), 1, 2, 40, 12, 8,
                             positive=True)
    a = rm_attention_scan_ref(zq, zk, v)
    b_ = rm_attention_ref(zq, zk, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-4,
                               atol=1e-4)


def test_decode_steps_match_causal_prefill():
    """prefill T tokens then decode 4 more == causal attention over T+4."""
    b, h, t, f, dv = 1, 2, 24, 16, 8
    zq, zk, v = _rand_inputs(jax.random.PRNGKey(2), b, h, t + 4, f, dv,
                             positive=True)
    full = rm_attention_ref(zq, zk, v, causal=True)

    s, n = rm_attention_prefill_final_state(zk[:, :, :t], v[:, :, :t])
    outs = []
    for i in range(4):
        o, s, n = rm_attention_decode_step(
            zq[:, :, t + i], zk[:, :, t + i], v[:, :, t + i], s, n
        )
        outs.append(o)
    got = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(full[:, :, t:]), rtol=1e-4,
                               atol=1e-4)


def test_noncausal_matches_oracle():
    zq, zk, v = _rand_inputs(jax.random.PRNGKey(3), 2, 2, 32, 16, 8,
                             positive=True)
    got = rm_attention_noncausal(zq, zk, v)
    want = rm_attention_ref(zq, zk, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-4)


def test_rm_attention_converges_to_softmax_attention():
    """The whole point: with enough RM features of the exp kernel, linear
    attention over Z(q), Z(k) reproduces softmax attention."""
    b, h, t, dh, dv = 1, 1, 12, 8, 8
    key = jax.random.PRNGKey(4)
    kq, kk, kv = jax.random.split(key, 3)
    # bounded q, k (the framework l2-normalizes per head in rm mode)
    q = jax.random.normal(kq, (b, h, t, dh))
    k = jax.random.normal(kk, (b, h, t, dh))
    q = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
    k = k / jnp.linalg.norm(k, axis=-1, keepdims=True)
    v = jax.random.normal(kv, (b, h, t, dv))

    # exact softmax attention (causal), temperature 1
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k)
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask, scores, -jnp.inf)
    want = jnp.einsum("bhts,bhsd->bhtd", jax.nn.softmax(scores, axis=-1), v)

    kern = ExponentialDotProductKernel(1.0)
    errs = []
    for D in (256, 8192):
        fm = make_feature_map(kern, dh, D, jax.random.PRNGKey(7),
                              measure="proportional", stratified=True)
        zq = fm(q)
        zk = fm(k)
        got = rm_attention_ref(zq, zk, v, causal=True)
        errs.append(float(jnp.mean(jnp.abs(got - want))))
    assert errs[1] < errs[0]
    assert errs[1] < 0.15, errs
