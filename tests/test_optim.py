"""Optimizer, schedule and checkpoint/fault substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
)
from repro.optim.schedule import warmup_cosine, warmup_linear


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0]), "b": jnp.asarray(2.0)}
    opt = adamw_init(params)
    cfg = AdamWConfig(weight_decay=0.0)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, jnp.float32(0.05), cfg)
    assert float(loss(params)) < 1e-3


def test_adamw_frozen_leaves_not_updated():
    params = {"attn": {"wq": jnp.ones((4, 4)), "rm_omegas": jnp.ones((8, 4))}}
    opt = adamw_init(params)
    grads = jax.tree_util.tree_map(jnp.ones_like, params)
    new_params, _, _ = adamw_update(params, grads, opt, jnp.float32(0.1))
    assert not np.allclose(np.asarray(new_params["attn"]["wq"]), 1.0)
    np.testing.assert_array_equal(
        np.asarray(new_params["attn"]["rm_omegas"]), 1.0
    )


def test_weight_decay_skips_1d():
    params = {"w": jnp.ones((4, 4)), "scale": jnp.ones((4,))}
    opt = adamw_init(params)
    zero_g = jax.tree_util.tree_map(jnp.zeros_like, params)
    cfg = AdamWConfig(weight_decay=0.5)
    new_params, _, _ = adamw_update(params, zero_g, opt, jnp.float32(0.1), cfg)
    assert float(new_params["w"][0, 0]) < 1.0          # decayed
    assert float(new_params["scale"][0]) == 1.0        # not decayed


def test_grad_clipping():
    grads = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) > 100.0
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    # small grads untouched
    grads = {"a": jnp.full((10,), 1e-3)}
    clipped, _ = clip_by_global_norm(grads, 1.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]), 1e-3, rtol=1e-6)


def test_schedules_shape():
    for sched in (warmup_cosine, warmup_linear):
        lr0 = float(sched(0, 1e-3, 10, 100))
        lr_peak = float(sched(10, 1e-3, 10, 100))
        lr_end = float(sched(100, 1e-3, 10, 100))
        assert lr0 == 0.0 or lr0 < 1e-4
        assert abs(lr_peak - 1e-3) < 1e-4
        assert lr_end < lr_peak
